"""Serving layer: network calculus, DES simulator, aggregators, queues,
placement — including the property that the network-calculus T_q bound
dominates empirical queueing delay."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_shim import given, settings, st

from repro.core.profiles import ModelProfile, ModelZoo, SystemConfig
from repro.serving.aggregator import (AggState, ModalitySpec,
                                      PatientAggregator, agg_init,
                                      ingest_step, read_window_static)
from repro.serving.latency import (LatencyProfiler, arrival_curve,
                                   max_horizontal_distance, queueing_bound,
                                   service_curve)
from repro.serving.placement import (Placement, lpt_placement,
                                     plan_pod_ensemble)
from repro.serving.queues import TimestampedQueue
from repro.serving.simulator import SimConfig, simulate


# ------------------------------------------------------ network calculus
def test_arrival_curve_monotone():
    arr = np.sort(np.random.default_rng(0).uniform(0, 10, 50))
    dts = np.linspace(0, 10, 20)
    a = arrival_curve(arr, dts)
    assert np.all(np.diff(a) >= 0)
    assert a[-1] >= 50 - 1       # window of full span catches everything


def test_service_curve():
    dts = np.asarray([0.0, 1.0, 2.0])
    np.testing.assert_allclose(service_curve(2.0, 0.5, dts),
                               [0.0, 1.0, 3.0])


@given(st.integers(2, 40), st.floats(5.0, 100.0), st.integers(0, 10 ** 5))
@settings(max_examples=25, deadline=None)
def test_tq_bound_dominates_empirical(n_patients, mu, seed):
    """Property: the network-calculus bound >= the DES-observed max
    queueing delay, for a single-server queue at rate mu."""
    cfg = SimConfig(n_patients=n_patients, n_devices=1,
                    window_seconds=10.0, duration_seconds=60.0, seed=seed,
                    dispatch_overhead=0.0)
    cost = 1.0 / mu
    lam = n_patients / cfg.window_seconds
    if lam >= mu * 0.9:          # keep the queue stable
        return
    res = simulate([cost], cfg)
    if not len(res.queries):
        return
    bound = queueing_bound(res.arrivals, mu, cost)
    assert res.queue_delays().max() <= bound + 1e-6


def test_horizontal_distance_closed_form():
    dts = np.linspace(0, 10, 101)
    alpha = np.minimum(5 + 2 * dts, 40.0)
    h = max_horizontal_distance(dts, alpha, mu=4.0, T0=0.1)
    want = max(0.1 + alpha / 4.0 - dts)
    assert h == pytest.approx(want)


# ------------------------------------------------------------ simulator
def test_simulator_latency_scales_with_patients():
    lat = []
    for n in (8, 64, 256):
        cfg = SimConfig(n_patients=n, n_devices=2, duration_seconds=90,
                        window_seconds=10, seed=1)
        r = simulate([0.02, 0.03], cfg)
        lat.append(r.p(95))
    assert lat[2] >= lat[0]       # more load, no faster


def test_simulator_more_devices_not_slower():
    cfg1 = SimConfig(n_patients=128, n_devices=1, duration_seconds=60,
                     window_seconds=10)
    cfg2 = SimConfig(n_patients=128, n_devices=4, duration_seconds=60,
                     window_seconds=10)
    c = [0.02, 0.02, 0.02]
    assert simulate(c, cfg2).p(95) <= simulate(c, cfg1).p(95) + 1e-9


def test_offline_batching_order_of_magnitude_slower():
    costs = [0.02]
    online = simulate(costs, SimConfig(n_patients=1, duration_seconds=600,
                                       window_seconds=30))
    offline = simulate(costs, SimConfig(n_patients=1, duration_seconds=600,
                                        window_seconds=30,
                                        batch_period=600))
    assert offline.p(95) > 10 * online.p(95)


# ------------------------------------------------------------ aggregator
def test_patient_aggregator_alignment():
    mods = [ModalitySpec("ecg", 10.0, 2), ModalitySpec("vitals", 1.0, 3)]
    agg = PatientAggregator(mods, window_seconds=5.0)
    for t in range(50):                   # 10 Hz ecg
        agg.ingest(t * 0.1, "ecg", np.ones((2, 1)) * t)
    for t in range(5):                    # 1 Hz vitals
        agg.ingest(float(t), "vitals", np.ones((3, 1)) * t)
    assert agg.window_ready(5.0)
    w = agg.pop_window(5.0)
    assert w["ecg"].shape == (2, 50)
    assert w["vitals"].shape == (3, 5)


def test_patient_aggregator_missing_data_zero_fill():
    mods = [ModalitySpec("ecg", 10.0, 1)]
    agg = PatientAggregator(mods, window_seconds=2.0)
    agg.ingest(0.0, "ecg", np.ones((1, 3)))
    agg.ingest(2.0, "ecg", np.ones((1, 1)))
    w = agg.pop_window(2.0)
    assert w["ecg"].shape == (1, 20)      # padded to nominal count


def test_jit_ring_buffer_roundtrip():
    import jax.numpy as jnp
    st_ = agg_init(n_patients=2, channels=1, capacity=8)
    for i in range(12):                   # wraps the ring
        st_ = ingest_step(st_, jnp.asarray(0),
                          jnp.asarray([[float(i)]]))
    w = read_window_static(st_, 0, 4)
    np.testing.assert_allclose(np.asarray(w)[0], [8.0, 9.0, 10.0, 11.0])


# ------------------------------------------------------------ queues
def test_queue_wait_stats():
    q = TimestampedQueue()
    q.push(0.0, "a")
    q.push(1.0, "b")
    assert q.pop(2.0) == "a"
    assert q.pop(2.5) == "b"
    assert q.stats.mean_wait == pytest.approx((2.0 + 1.5) / 2)
    assert q.pop(3.0) is None


# ------------------------------------------------------------ placement
@given(st.lists(st.floats(0.001, 1.0), min_size=1, max_size=20),
       st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_lpt_makespan_bound(costs, k):
    pl = lpt_placement(costs, k)
    # list-scheduling guarantee: makespan <= sum/k + (1 - 1/k) * max
    assert pl.makespan <= sum(costs) / k \
        + (1 - 1 / k) * max(costs) + 1e-9
    assert pl.makespan >= max(max(costs), sum(costs) / k) - 1e-9
    placed = sorted(i for dev in pl.assignment for i in dev)
    assert placed == list(range(len(costs)))


def test_plan_pod_ensemble():
    out = plan_pod_ensemble({"a": 1.0, "b": 0.9, "c": 0.1}, 2)
    assert set(out.values()) <= {0, 1}
    assert out["a"] != out["b"]           # two heavy members split


# ------------------------------------------------------------ profiler
def _tiny_zoo():
    profs = [ModelProfile(f"m{i}", depth=2, width=8, macs=1e6 * (i + 1),
                          memory_bytes=1e6, modality=0, input_len=100,
                          val_auc=0.8) for i in range(4)]
    return ModelZoo(profs)


def test_latency_profiler_monotone_in_ensemble_size():
    prof = LatencyProfiler(_tiny_zoo(), SystemConfig(n_devices=2,
                                                     n_patients=16))
    l1 = prof(np.asarray([1, 0, 0, 0]))
    l2 = prof(np.asarray([1, 1, 1, 1]))
    assert l2 >= l1


def test_latency_profiler_memory_infeasible():
    zoo = _tiny_zoo()
    cfgc = SystemConfig(n_devices=1, device_mem_bytes=2e6)
    prof = LatencyProfiler(zoo, cfgc)
    assert prof(np.asarray([1, 1, 1, 1])) >= prof.infeasible_latency


def test_latency_profiler_unstable_queue():
    prof = LatencyProfiler(
        _tiny_zoo(), SystemConfig(n_devices=1, n_patients=10_000,
                                  window_seconds=1.0),
        cost_fn=lambda i: 0.01)
    assert prof(np.asarray([1, 1, 1, 1])) >= prof.infeasible_latency


def test_latency_profiler_call_threads_active_placement():
    """REGRESSION: ``__call__`` used to compute T_s from a FRESH LPT
    plan even when the caller held the ACTIVE placement — e.g. the
    deliberately unbalanced interim plan installed by failover — so the
    estimate understated latency exactly when the controller's risk
    prediction mattered most.  Pre-fix this call raised TypeError
    (no ``placement=`` parameter)."""
    cfg = SystemConfig(n_devices=2, n_patients=4, window_seconds=10.0)
    prof = LatencyProfiler(_tiny_zoo(), cfg,
                           cost_fn=lambda i: 0.01 * (i + 1))
    b = np.asarray([1, 1, 1, 1])
    skewed = Placement(assignment=[[0, 1, 2, 3], []], loads=[0.1, 0.0])
    assert prof.serving_latency(b, placement=skewed) \
        > prof.serving_latency(b)
    assert prof(b, placement=skewed) > prof(b)


def test_latency_profiler_hetero_speeds():
    """Heterogeneous pool: mu = sum(speeds)/sum(costs), and a
    speed-aware T_s plan beats the homogeneous one when one device is
    4x faster.  Unit speeds reduce to the default exactly."""
    cfg = SystemConfig(n_devices=2, n_patients=4, window_seconds=10.0)
    cost = lambda i: 0.1 * (i + 1)                      # noqa: E731
    b = np.asarray([1, 1, 1, 1])
    base = LatencyProfiler(_tiny_zoo(), cfg, cost_fn=cost)
    fast = LatencyProfiler(_tiny_zoo(), cfg, cost_fn=cost,
                           device_speeds=[1.0, 4.0])
    unit = LatencyProfiler(_tiny_zoo(), cfg, cost_fn=cost,
                           device_speeds=[1.0, 1.0])
    assert base.throughput(b) == pytest.approx(2.0 / 1.0)
    assert fast.throughput(b) == pytest.approx(5.0 / 1.0)
    assert fast.serving_latency(b) < base.serving_latency(b)
    assert unit.serving_latency(b) == base.serving_latency(b)
    assert unit.throughput(b) == base.throughput(b)


def test_latency_profiler_rejects_bad_speed_length():
    prof = LatencyProfiler(_tiny_zoo(), SystemConfig(n_devices=2),
                           device_speeds=[1.0])
    with pytest.raises(ValueError):
        prof.throughput(np.asarray([1, 0, 0, 0]))
