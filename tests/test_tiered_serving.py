"""Tiered-serving invariants: per-acuity-tier degradation ladders with
priority-aware shedding.

Four layers:

* DES conservation properties (hypothesis-or-shim): under census churn
  AND mid-stay acuity escalation, every query is served exactly once,
  by exactly its birth-tier's ensemble (never dropped, double-served,
  or answered by the wrong tier's selector), per-tier counts sum to the
  fleet totals, and tiered backlog carry preserves tiers across epoch
  edges;
* controller policy properties: shed-order monotonicity — after ANY
  sequence of controller actions a stable bed is never on a richer
  rung than a critical bed — plus the critical-tier holdout (sheds only
  when the predicted bound leaves no alternative) and the cross-tier
  climb budget;
* data-plane routing: tier-keyed micro-batching never mixes tiers in a
  flush, and each query's score is bitwise-equal to a cold service on
  its tier's selector;
* shared staging: zero-drop tier-pair hot swaps mid-stream, and
  eviction with tier-keyed composite cache keys never evicts another
  tier's active pair (T tiers x R rungs stage R services, not T*R).

Everything here is device-count-agnostic: the file must pass unchanged
in the default single-device lane and the forced-8-device CI lane.
"""
import threading
import time
from collections import Counter

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_shim import given, settings, st

from repro.control.controller import (Decision, TieredController,
                                      TieredControllerConfig)
from repro.control.swap import SelectorLadder
from repro.control.telemetry import TelemetrySnapshot, TieredTelemetry
from repro.control.tiers import TIER_ORDER, TieredEnsemble, TierRegistry
from repro.serving.queues import NO_LANE, KeyedMicroBatcher
from repro.serving.simulator import SimConfig, simulate

TIERS = TIER_ORDER                    # ("stable", "elevated", "critical")
FRACS = {"stable": 0.5, "elevated": 0.3, "critical": 0.2}
COSTS = {"stable": [0.01], "elevated": [0.01, 0.02],
         "critical": [0.01, 0.02, 0.03]}


def _sel(n, idx):
    b = np.zeros(n, np.int8)
    b[list(idx)] = 1
    return b


def _tier_at(tier_log, patient, t):
    """Tier of ``patient`` at time ``t`` per the acuity trail (None if
    never admitted by then)."""
    cur = None
    for tt, p, _old, new in tier_log:
        if p == patient and tt <= t:
            cur = new
    return cur


# ----------------------------------------------------------- registry
def test_registry_assign_escalate_default():
    reg = TierRegistry()
    assert reg.tier_of(7) == "stable"          # unknown -> lowest acuity
    reg.assign(7, "critical")
    assert reg.tier_of(7) == "critical"
    assert reg.escalate(3) == "elevated"       # one step up from default
    assert reg.escalate(3) == "critical"
    assert reg.escalate(3) == "critical"       # top is sticky
    assert reg.census() == {"stable": 0, "elevated": 0, "critical": 2}
    reg.discharge(7)
    assert reg.tier_of(7) == "stable"
    with pytest.raises(ValueError):
        reg.assign(1, "nonexistent")


# ------------------------------------------- DES per-tier conservation
@given(st.integers(0, 10**6), st.integers(1, 3),
       st.floats(0.0, 0.4))
@settings(max_examples=8, deadline=None)
def test_tiered_churn_conserves_queries_per_tier(seed, devices, hazard):
    """Under churn + escalation: every query carries a real tier, is
    served with exactly its tier's ensemble size, per-tier counts sum
    to the totals, and the stamped tier matches the acuity trail at
    birth (no query answered by the wrong tier's selector)."""
    cfg = SimConfig(window_seconds=5.0, duration_seconds=60.0,
                    census=[(0.0, 6), (20.0, 14), (40.0, 4)],
                    seed=seed, n_devices=devices,
                    tiers=FRACS, escalate_hazard=hazard)
    r = simulate(COSTS, cfg)
    assert len(r.queries) == len(r.arrivals)   # drain mode: all served
    per = {t: 0 for t in FRACS}
    for q in r.queries:
        assert q.tier in FRACS
        assert q.n_models == len(COSTS[q.tier])
        assert q.t_done > q.t_window           # served exactly once
        assert q.tier == _tier_at(r.tier_log, q.patient, q.t_window)
        per[q.tier] += 1
    assert sum(per.values()) == len(r.queries)
    # the acuity trail only admits (old == "") or escalates one step up
    order = list(FRACS)
    for _t, _p, old, new in r.tier_log:
        if old:
            assert order.index(new) == order.index(old) + 1


def test_tiered_churn_deterministic_under_seed():
    cfg = SimConfig(window_seconds=5.0, duration_seconds=60.0,
                    census=[(0.0, 8), (30.0, 16)], seed=11,
                    tiers=FRACS, escalate_hazard=0.25)
    r1, r2 = simulate(COSTS, cfg), simulate(COSTS, cfg)
    assert r1.tier_log == r2.tier_log
    assert [q.tier for q in r1.queries] == [q.tier for q in r2.queries]
    np.testing.assert_array_equal(r1.arrivals, r2.arrivals)


def test_mid_stay_escalation_conservation():
    """The acceptance property: acuity escalating mid-stay moves the
    patient's NEXT queries to the higher tier — queries before the
    escalation keep the old tier, queries after carry the new one, and
    nothing is lost or double-served along the way."""
    cfg = SimConfig(window_seconds=4.0, duration_seconds=80.0,
                    census=[(0.0, 10)], seed=2,
                    tiers=FRACS, escalate_hazard=0.3)
    r = simulate(COSTS, cfg)
    esc = [e for e in r.tier_log if e[2]]
    assert esc                                 # escalations did happen
    assert len(r.queries) == len(r.arrivals)
    by_patient = {}
    for q in r.queries:
        by_patient.setdefault(q.patient, []).append(q)
    # some patient really straddled tiers mid-stay...
    multi = sum(1 for qs in by_patient.values()
                if len({q.tier for q in qs}) > 1)
    assert multi > 0
    # ...tiers only ever move UP along a patient's own query stream
    # (this DES models escalation, not de-escalation)...
    order = list(FRACS)
    for qs in by_patient.values():
        idx = [order.index(q.tier)
               for q in sorted(qs, key=lambda q: q.t_window)]
        assert idx == sorted(idx)
    # ...and every query's tier matches the acuity trail at its birth
    for q in r.queries:
        assert q.tier == _tier_at(r.tier_log, q.patient, q.t_window)


@given(st.integers(0, 10**6))
@settings(max_examples=6, deadline=None)
def test_tiered_backlog_preserves_tiers_across_epochs(seed):
    """Epoch-edge conservation, per tier: born = served + carried, and
    a carried query enters the next epoch with its birth tier."""
    slow = {t: [0.25] for t in FRACS}
    cfg = SimConfig(n_patients=24, n_devices=1, window_seconds=5.0,
                    duration_seconds=40.0, seed=seed,
                    carry_backlog=True, tiers=FRACS)
    r1 = simulate(slow, cfg)
    assert len(r1.backlog) > 0
    assert len(r1.backlog_tiers) == len(r1.backlog)
    # epoch-1 conservation, per tier: born = served + carried out
    born1 = Counter(q.tier for q in r1.queries) \
        + Counter(r1.backlog_tiers)
    assert sum(born1.values()) == len(r1.arrivals)
    r2 = simulate({t: [0.02] for t in FRACS}, cfg,
                  backlog=r1.backlog, backlog_tiers=r1.backlog_tiers)
    from_backlog = [q for q in r2.queries if q.t_window < 0]
    # every carried query either retired in epoch 2 or carried again
    assert len(from_backlog) + sum(
        1 for a in r2.backlog if a > cfg.duration_seconds) \
        == len(r1.backlog)
    # tiers preserved: the multiset of retired-backlog tiers is a
    # sub-multiset of what was carried in
    cin = Counter(r1.backlog_tiers)
    cout = Counter(q.tier for q in from_backlog)
    assert all(cout[t] <= cin[t] for t in cout)
    # and the carried queries were served with their OWN tier's costs
    for q in from_backlog:
        assert q.n_models == len(slow[q.tier])


def test_escalation_requires_tiers():
    with pytest.raises(ValueError):
        simulate([0.01], SimConfig(n_patients=2, escalate_hazard=0.5))


# ---------------------------------------------- controller: shed order
class _NoopLadder(SelectorLadder):
    def _activate(self, selector):
        pass


def _family(n_rungs=3, n=8):
    return [_sel(n, range(k + 1)) for k in range(n_rungs)]


def _lanes(pos=None):
    fam = _family()
    lanes = {}
    for i, t in enumerate(TIERS):
        p = (len(fam) - 1) if pos is None else pos[i]
        lane = _NoopLadder(fam[p])
        lane.set_ladder(fam)
        lanes[t] = lane
    return lanes, fam


class _ScriptedTelemetry:
    """Controller-facing stub: the test scripts the fleet snapshot and
    per-tier arrival rates directly."""

    def __init__(self, rates=None):
        self.tiers = TIERS
        self.slo = 1.0
        self.fleet = None
        self.rates = dict(rates or {t: 1.0 for t in TIERS})

    def snapshot(self, **kw):
        return self.fleet

    def tier_snapshot(self, tier, **kw):
        return _snap(arrival_rate=self.rates[tier])


def _snap(**kw):
    base = dict(t=0.0, window_seconds=30.0, n_arrivals=100, n_served=100,
                n_shed=0, arrival_rate=2.0, p50=0.1, p99=0.2,
                violation_rate=0.0)
    base.update(kw)
    return TelemetrySnapshot(**base)


def _assert_monotone(lanes):
    pos = [lanes[t].ladder_pos for t in TIERS]
    assert all(p >= 0 for p in pos)
    assert all(a <= b for a, b in zip(pos, pos[1:])), pos


@given(st.lists(st.booleans(), min_size=1, max_size=30))
@settings(max_examples=20, deadline=None)
def test_shed_order_monotone_under_any_action_sequence(overloads):
    """THE invariant: whatever sequence of overloaded/healthy evidence
    the controller sees, a stable bed is never on a richer rung than an
    elevated bed, nor an elevated bed richer than a critical bed."""
    lanes, _fam = _lanes()
    tel = _ScriptedTelemetry()
    ctl = TieredController(
        tel, lanes, tier_order=TIERS,
        config=TieredControllerConfig(slo_seconds=1.0,
                                      cooldown_seconds=0.0,
                                      min_samples=1))
    for k, overloaded in enumerate(overloads):
        tel.fleet = _snap(violation_rate=0.5 if overloaded else 0.0,
                          p99=1.5 if overloaded else 0.1)
        ctl.step(now=float(k))
        _assert_monotone(lanes)
        assert ctl.monotone()
        # priority: if the critical tier ever shed, every lower tier
        # must already be at (or have stayed at) the floor
        if lanes["critical"].ladder_pos < len(_fam) - 1:
            assert lanes["stable"].ladder_pos == 0
            assert lanes["elevated"].ladder_pos == 0


def test_critical_holds_while_floor_restores_capacity():
    """With a cost model showing that flooring the lower tiers restores
    feasibility (rho_floor < 1), the critical tier NEVER sheds no
    matter how long the observed overload persists."""
    lanes, fam = _lanes()
    tel = _ScriptedTelemetry(rates={t: 1.0 for t in TIERS})
    costs = np.linspace(0.01, 0.05, 8)
    ctl = TieredController(
        tel, lanes, tier_order=TIERS,
        config=TieredControllerConfig(slo_seconds=1.0,
                                      cooldown_seconds=0.0,
                                      min_samples=1, rho_max=0.5),
        cost_fn=lambda s: float(costs[np.asarray(s, bool)].sum()),
        n_devices=1)
    for k in range(12):
        tel.fleet = _snap(violation_rate=0.9, p99=3.0)
        ctl.step(now=float(k))
        _assert_monotone(lanes)
    assert lanes["stable"].ladder_pos == 0         # floored
    assert lanes["elevated"].ladder_pos == 0       # floored
    assert lanes["critical"].ladder_pos == len(fam) - 1   # held rich


def test_critical_sheds_when_no_alternative():
    """rho >= 1 even with every lower tier floored: the predicted bound
    leaves no alternative, so the critical tier finally sheds too."""
    lanes, fam = _lanes()
    tel = _ScriptedTelemetry(rates={"stable": 1.0, "elevated": 1.0,
                                    "critical": 40.0})
    costs = np.linspace(0.01, 0.05, 8)
    ctl = TieredController(
        tel, lanes, tier_order=TIERS,
        config=TieredControllerConfig(slo_seconds=1.0,
                                      cooldown_seconds=0.0,
                                      min_samples=1, rho_max=0.5),
        cost_fn=lambda s: float(costs[np.asarray(s, bool)].sum()),
        n_devices=1)
    for k in range(12):
        tel.fleet = _snap(violation_rate=0.9, p99=3.0)
        ctl.step(now=float(k))
        _assert_monotone(lanes)
    assert lanes["stable"].ladder_pos == 0
    assert lanes["elevated"].ladder_pos == 0
    assert lanes["critical"].ladder_pos == 0       # forced all the way
    sheds = [(t, tier) for t, tier, d in ctl.log if d is Decision.SHED]
    first_critical = next(i for i, (_, tier) in enumerate(sheds)
                          if tier == "critical")
    # every stable/elevated shed happened BEFORE the first critical one
    assert all(tier != "critical"
               for _, tier in sheds[:first_critical])


def test_queries_already_dropping_is_no_alternative():
    """n_shed > 0 (the ingest queue is rejecting) counts as 'no
    alternative' even without a cost model."""
    lanes, fam = _lanes(pos=[0, 0, len(_family()) - 1])
    tel = _ScriptedTelemetry()
    ctl = TieredController(
        tel, lanes, tier_order=TIERS,
        config=TieredControllerConfig(slo_seconds=1.0,
                                      cooldown_seconds=0.0,
                                      min_samples=1))
    tel.fleet = _snap(violation_rate=0.0, p99=0.9, n_shed=5)
    acts = ctl.step(now=0.0)
    assert (Decision.SHED, "critical") in acts


def test_climb_order_critical_first_and_budget_gated():
    """Recovery: the critical tier climbs back FIRST; a lower tier may
    never climb past a higher tier's rung; and when the cross-tier
    budget is tight, low-acuity climbs are denied so they cannot eat
    the critical tier's headroom."""
    lanes, fam = _lanes(pos=[0, 0, 0])
    tel = _ScriptedTelemetry(rates={t: 1.0 for t in TIERS})
    costs = np.linspace(0.01, 0.05, 8)
    cost_fn = lambda s: float(costs[np.asarray(s, bool)].sum())
    ctl = TieredController(
        tel, lanes, tier_order=TIERS,
        config=TieredControllerConfig(slo_seconds=1.0,
                                      cooldown_seconds=0.0,
                                      min_samples=1, rho_max=10.0),
        cost_fn=cost_fn, n_devices=1)
    climbs = []
    for k in range(12):
        tel.fleet = _snap(violation_rate=0.0, p99=0.1)
        acts = ctl.step(now=float(k))
        climbs.extend(tier for d, tier in acts if d is Decision.CLIMB)
        _assert_monotone(lanes)
    # critical reaches the top before elevated starts, elevated before
    # stable (priority order holds throughout by monotonicity)
    assert climbs[:2] == ["critical", "critical"]
    assert lanes["critical"].ladder_pos == len(fam) - 1
    assert lanes["stable"].ladder_pos == len(fam) - 1   # budget is loose

    # tight budget: from the floor, only the critical tier fits
    lanes2, _ = _lanes(pos=[0, 0, 0])
    rates = {t: 10.0 for t in TIERS}
    tel2 = _ScriptedTelemetry(rates=rates)
    base_rho = sum(rates[t] * cost_fn(lanes2[t].active_selector)
                   for t in TIERS)
    rho_max = base_rho + 10.0 * (cost_fn(_family()[2]) * 1.1)
    ctl2 = TieredController(
        tel2, lanes2, tier_order=TIERS,
        config=TieredControllerConfig(slo_seconds=1.0,
                                      cooldown_seconds=0.0,
                                      min_samples=1, rho_max=rho_max),
        cost_fn=cost_fn, n_devices=1)
    for k in range(12):
        tel2.fleet = _snap(violation_rate=0.0, p99=0.1)
        ctl2.step(now=float(k))
        _assert_monotone(lanes2)
    assert lanes2["critical"].ladder_pos == len(fam) - 1
    assert lanes2["stable"].ladder_pos == 0    # denied: no headroom


# -------------------------------------------------- per-tier telemetry
def test_tiered_telemetry_slices_and_fleet():
    reg = TierRegistry()
    reg.assign(1, "critical")
    tel = TieredTelemetry(tier_of=reg.tier_of, tiers=TIERS,
                          slo_seconds=0.5, window_seconds=60.0,
                          clock=lambda: 10.0)
    tel.record_arrival(1.0, patient=1)            # -> critical
    tel.record_arrival(1.5, patient=99)           # unknown -> stable
    tel.record_arrival(2.0, tier="elevated")      # explicit tier wins
    tel.record_served(0.1, 2.5, patient=1)
    tel.record_served(0.9, 3.0, tier="stable")    # violates
    assert tel.tier_snapshot("critical").n_arrivals == 1
    assert tel.tier_snapshot("stable").n_arrivals == 1
    assert tel.tier_snapshot("elevated").n_arrivals == 1
    assert tel.tier_snapshot("critical").n_served == 1
    assert tel.tier_snapshot("stable").violation_rate == 1.0
    assert tel.tier_snapshot("critical").violation_rate == 0.0
    fleet = tel.snapshot()
    assert fleet.n_arrivals == 3 and fleet.n_served == 2
    # explicit tier unknown -> default slice, never lost
    tel.record_arrival(4.0, tier="no-such-tier")
    assert tel.tier_snapshot("stable").n_arrivals == 2


# ------------------------------------------------ tier-keyed batching
def test_keyed_batcher_never_mixes_keys():
    t = [0.0]
    kb = KeyedMicroBatcher(max_batch=3, max_wait_ms=1000.0,
                           clock=lambda: t[0])
    for i in range(3):
        kb.push("a", ("a", i))
    kb.push("b", ("b", 0))
    assert len(kb) == 4
    assert kb.ready() == "a"                   # a hit max_batch
    batch = kb.pop_batch("a")
    assert [k for k, _ in batch] == ["a", "a", "a"]
    assert kb.ready() is NO_LANE               # b neither full nor old
    t[0] = 2.0
    assert kb.ready() == "b"                   # b aged past max_wait
    assert [k for k, _ in kb.pop_batch("b")] == ["b"]
    assert kb.stats.n_flushes == 2 and kb.stats.n_items == 4


def test_keyed_batcher_oldest_due_first():
    t = [0.0]
    kb = KeyedMicroBatcher(max_batch=8, max_wait_ms=100.0,
                           clock=lambda: t[0])
    kb.push("late", 1, t=0.5)
    kb.push("early", 2, t=0.1)
    t[0] = 1.0                                 # both lanes are due
    assert kb.ready() == "early"
    kb.pop_batch("early")
    assert kb.ready() == "late"


def test_keyed_batcher_stats_never_torn_under_concurrent_pops():
    """Regression: ``KeyedMicroBatcher.stats``/``lane_stats`` used to
    expose the LIVE per-lane stats objects, which ``pop_batch`` mutates
    field by field under the lane lock the reader never takes.  Any
    consumer that combines two fields read at different moments — the
    aggregate loop, a metrics exporter formatting one line per field —
    sees values from different flushes.  With max_batch=1 every flush
    carries exactly one item, so ANY consistent view has
    ``n_flushes <= n_items``; a live object read across an ongoing pop
    stream violates it (``n_items`` from before a flush, ``n_flushes``
    from after).  Both surfaces must return internally-consistent
    snapshots no matter how slowly the caller consumes the fields."""
    kb = KeyedMicroBatcher(max_batch=1, max_wait_ms=0.0)
    stop = threading.Event()
    torn = []

    def popper(lane):
        i = 0
        while not stop.is_set():
            kb.push(lane, i)
            kb.pop_batch(lane)
            i += 1

    def reader():
        while not stop.is_set():
            # Field reads deliberately straddle a delay: a snapshot is
            # immutable so this is safe; a live lane object tears.
            views = [("agg", kb.stats)]
            views += [(k, ls) for k, ls in kb.lane_stats().items()]
            items = [(k, v.n_items) for k, v in views]
            time.sleep(0.002)          # pops keep landing in between
            for (k, v), (_, n_it) in zip(views, items):
                if v.n_flushes > n_it:
                    torn.append((k, n_it, v.n_flushes))

    threads = ([threading.Thread(target=popper, args=(ln,))
                for ln in ("a", "b")]
               + [threading.Thread(target=reader) for _ in range(2)])
    for th in threads:
        th.start()
    time.sleep(1.0)
    stop.set()
    for th in threads:
        th.join()
    assert torn == []
    s = kb.stats                       # quiescent: exact equality
    assert s.n_flushes == s.n_items > 0


def test_server_coalesces_within_tier_only():
    from repro.serving.server import EnsembleServer
    reg = TierRegistry()
    for p in range(30):
        reg.assign(p, TIERS[p % 3])
    flushes = []

    def handler(windows, tier):
        flushes.append((tier, [w["p"] for w in windows]))
        return [float(tier == "critical")] * len(windows)

    srv = EnsembleServer(batch_handler=handler, tier_of=reg.tier_of,
                         n_workers=2, max_batch=4, max_wait_ms=2.0)
    for p in range(30):                        # enqueue before starting
        assert srv.submit(p, {"p": p})         # so batches can coalesce
    srv.start()
    stats = srv.stop()
    assert stats.served == 30                  # zero dropped
    for tier, pids in flushes:
        assert all(reg.tier_of(p) == tier for p in pids)
    assert any(len(pids) > 1 for _, pids in flushes)   # did coalesce
    scores = {p: s for p, s, *_ in srv.results()}
    for p in range(30):                        # answered by its tier
        assert scores[p] == float(reg.tier_of(p) == "critical")


class _StubService:
    def __init__(self, v):
        self.v = v

    def predict(self, windows):
        return self.v

    def predict_batch(self, batch):
        return [self.v] * len(batch)


def test_tier_router_dispatch_and_fallback():
    from repro.serving.pipeline import TierRouter
    router = TierRouter({"stable": _StubService(0.1),
                         "critical": _StubService(0.9)},
                        default="stable")
    assert router.predict({}, "critical") == 0.9
    assert router.predict({}) == 0.1               # no tier -> default
    assert router.predict({}, "unknown") == 0.1    # unknown -> default
    assert router.predict_batch([{}, {}], "critical") == [0.9, 0.9]
    with pytest.raises(ValueError):
        TierRouter({})
    with pytest.raises(ValueError):
        TierRouter({"stable": _StubService(0.0)}, default="missing")


def test_streaming_pipeline_routes_through_tier_router():
    """Each closed window is answered by the patient's CURRENT tier's
    service — the StreamingPipeline face of tier routing."""
    from repro.serving.pipeline import StreamingPipeline, TierRouter
    reg = TierRegistry()
    reg.assign(1, "critical")
    router = TierRouter({"stable": _StubService(0.1),
                         "critical": _StubService(0.9)},
                        default="stable")
    pipe = StreamingPipeline(router, n_patients=2, window_seconds=1.0,
                             tier_of=reg.tier_of)
    recs = {}
    for patient in (0, 1):
        pipe.feed(0.0, patient, "ecg", np.zeros((3, 10), np.float32))
        recs[patient] = pipe.feed(1.5, patient, "ecg",
                                  np.zeros((3, 10), np.float32))
    assert recs[0].score == 0.1                # stable bed, stable svc
    assert recs[1].score == 0.9                # critical bed, its svc
    reg.escalate(0)                            # mid-stay deterioration
    assert reg.escalate(0) == "critical"       # stable -> elev -> crit
    pipe.feed(3.0, 0, "ecg", np.zeros((3, 10), np.float32))
    rec = pipe.feed(4.6, 0, "ecg", np.zeros((3, 10), np.float32))
    assert rec.score == 0.9                    # next window: new tier


def test_staging_unregister_releases_dead_lane_pins(zoo_members):
    """A lane retired from a shared StagingCache stops pinning its
    pairs: a later eviction pass may finally drop them."""
    from repro.control.swap import HotSwapper
    n = len(zoo_members)
    rungs = _rungs(n)
    te = TieredEnsemble(zoo_members,
                        initial={"stable": rungs[0],
                                 "elevated": rungs[1],
                                 "critical": rungs[2]},
                        warmup_batch_sizes=(1,))
    te.set_ladder(rungs)
    dead = HotSwapper(zoo_members, _sel(n, [3, 5]),
                      staging=te.staging, warmup_batch_sizes=(1,))
    assert len(te.staging.lanes) == 4
    assert len(te.staging.staged) == len(rungs) + 1
    te.staging.unregister(dead)
    assert len(te.staging.lanes) == 3
    te.lane("stable").swap_to(_sel(n, [4]))    # triggers an evict pass
    te.lane("stable").swap_to(rungs[0])
    assert _sel(n, [3, 5]).tobytes() not in {
        k.split(b"|", 1)[0] for k in te.staging.staged}


def test_tiered_controller_rejects_mismatched_slo():
    lanes, _ = _lanes()
    tel = _ScriptedTelemetry()                 # slo = 1.0
    with pytest.raises(ValueError):
        TieredController(
            tel, lanes, tier_order=TIERS,
            config=TieredControllerConfig(slo_seconds=2.0))


def test_tier_of_requires_batch_handler():
    from repro.serving.server import EnsembleServer
    with pytest.raises(ValueError):
        EnsembleServer(handler=lambda w: 0.0,
                       tier_of=lambda p: "stable")


def test_failing_tier_of_routes_to_default_not_dead_worker():
    """A tier_of callback raising on an unknown patient must not kill
    the worker or strand the query: it routes to the default lane and
    every submitted query is still served."""
    from repro.serving.server import EnsembleServer
    seen = []

    def bad_tier(p):
        if p == 3:
            raise KeyError(p)
        return TIERS[p % 3]

    def handler(windows, tier):
        seen.append((tier, [w["p"] for w in windows]))
        return [0.0] * len(windows)

    srv = EnsembleServer(batch_handler=handler, tier_of=bad_tier,
                         n_workers=1, max_batch=2,
                         max_wait_ms=1.0).start()
    for p in range(6):
        assert srv.submit(p, {"p": p})
    stats = srv.stop()
    assert stats.served == 6                  # nothing stranded
    tier_of_3 = [t for t, pids in seen if 3 in pids]
    assert tier_of_3 == [None]                # default-lane fallback


# ------------------------------------- shared staging + zero-drop swap
def _rungs(n):
    return [_sel(n, [0]), _sel(n, range(0, n, 2)), _sel(n, range(n))]


def test_tier_staging_shares_pairs_across_tiers(zoo_members):
    """T tiers x R rungs stage R services, not T*R: tiers standing on
    the same (selector, placement) pair serve through the SAME staged
    object."""
    n = len(zoo_members)
    rungs = _rungs(n)
    te = TieredEnsemble(zoo_members,
                        initial={"stable": rungs[0],
                                 "elevated": rungs[1],
                                 "critical": rungs[2]},
                        warmup_batch_sizes=(1,))
    te.set_ladder(rungs)
    assert len(te.staging.staged) == len(rungs)
    assert te.rungs() == {"stable": 0, "elevated": 1, "critical": 2}
    assert te.monotone()
    # a tier moving onto another tier's rung reuses its staged service
    te.lane("stable").climb()
    assert te.lane("stable").facade.current \
        is te.lane("elevated").facade.current
    assert len(te.staging.staged) == len(rungs)


def test_tier_eviction_never_evicts_other_tiers_active(zoo_members):
    """Satellite acceptance (seeded, deterministic): one tier churning
    through novel off-ladder pairs triggers evictions, but no other
    tier's ACTIVE pair (nor any ladder rung) is ever evicted."""
    n = len(zoo_members)
    rungs = _rungs(n)
    te = TieredEnsemble(zoo_members,
                        initial={"stable": rungs[0],
                                 "elevated": rungs[1],
                                 "critical": rungs[2]},
                        warmup_batch_sizes=(1,))
    te.set_ladder(rungs)
    crit_svc = te.lane("critical").facade.current
    elev_svc = te.lane("elevated").facade.current
    for k in range(1, 5):                     # novel off-ladder pairs
        te.lane("stable").swap_to(_sel(n, [k, (k + 3) % n]))
        # other tiers' live services survived the eviction pass
        assert te.lane("critical").facade.current is crit_svc
        assert te.lane("elevated").facade.current is elev_svc
        # and every ladder rung stayed staged (shed/climb stays warm)
        staged_sels = {key.split(b"|", 1)[0] for key in te.staging.staged}
        for s in rungs:
            assert s.tobytes() in staged_sels
    # evicted down to: 3 rungs + stable's current novel pair
    assert len(te.staging.staged) == len(rungs) + 1


def test_tiered_hot_swap_zero_drop_mid_stream(zoo_members, rng):
    """Zero-drop tier-pair hot swaps: shedding one tier and escalating
    a patient mid-stream drops no queries, and post-swap scores are
    bitwise-equal to cold services on the right tier's selector."""
    from repro.serving.pipeline import EnsembleService
    from repro.serving.server import EnsembleServer
    n = len(zoo_members)
    rungs = _rungs(n)
    te = TieredEnsemble(zoo_members,
                        initial={"stable": rungs[2],
                                 "elevated": rungs[2],
                                 "critical": rungs[2]},
                        warmup_batch_sizes=(1,))
    te.set_ladder(rungs)
    for p in range(24):
        te.registry.assign(p, TIERS[p % 3])
    # max_batch=1: singleton flushes, so scores compare 1:1 to cold
    srv = EnsembleServer(batch_handler=te.predict_batch,
                         tier_of=te.tier_of, n_workers=2,
                         max_batch=1, max_wait_ms=0.5).start()
    windows = [{"ecg": rng.standard_normal((3, 250)).astype(np.float32)}
               for _ in range(24)]
    for i in range(12):
        assert srv.submit(i, windows[i])
    assert te.lane("stable").shed()            # tier-pair swap mid-stream
    te.registry.escalate(0)                    # stable bed deteriorates
    for i in range(12, 24):
        assert srv.submit(i, windows[i])
    stats = srv.stop()
    assert stats.served == 24                  # zero dropped
    assert te.lane("stable").facade.swap_count == 1
    assert te.monotone()
    # fresh post-drain queries land bitwise on the right tier's rung
    cold_mid = EnsembleService.for_selector(zoo_members, rungs[1])
    cold_full = EnsembleService.for_selector(zoo_members, rungs[2])
    w = windows[0]
    assert te.predict(w, "stable") == cold_mid.predict_batch([w])[0]
    assert te.predict(w, "critical") == cold_full.predict_batch([w])[0]
    # the escalated patient now routes to the elevated (rich) lane
    assert te.tier_of(0) == "elevated"
    assert te.predict(w, te.tier_of(0)) == cold_full.predict_batch([w])[0]
