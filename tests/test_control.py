"""Online adaptive control plane: telemetry, controller policy,
hot-swap correctness (zero dropped queries, bitwise post-swap
equality), simulator churn determinism/conservation, the vectorized
arrival curve, thread-safe ServerStats, and the incremental
``recompose`` warm-start API."""
import threading
import time

import numpy as np
import pytest

from repro.control.controller import (AdaptiveController, ControllerConfig,
                                      Decision)
from repro.control.swap import HotSwapper, SelectorLadder, SwappableService
from repro.control.telemetry import SloTelemetry, TelemetrySnapshot
from repro.obs.sketch import REL_ERR_BOUND
from repro.core.composer import ComposerParams, compose, recompose
from repro.serving.latency import arrival_curve, queueing_bound
from repro.serving.pipeline import EnsembleService
from repro.serving.server import EnsembleServer, ServerStats
from repro.serving.simulator import SimConfig, simulate

from test_composer import make_testbed


# ------------------------------------------------- vectorized alpha(dt)
def _arrival_curve_ref(arrivals, dts):
    a = np.sort(np.asarray(arrivals, np.float64))
    out = []
    for dt in dts:
        best = 0
        for i in range(len(a)):
            best = max(best, int(np.sum((a >= a[i]) & (a < a[i] + dt))))
        out.append(best)
    return np.asarray(out, np.float64)


def test_arrival_curve_matches_reference():
    rng = np.random.default_rng(3)
    for n in (1, 2, 17, 60):
        arr = rng.uniform(0, 10, n)
        dts = np.concatenate([[0.0], rng.uniform(0, 12, 9)])
        np.testing.assert_array_equal(arrival_curve(arr, dts),
                                      _arrival_curve_ref(arr, dts))


def test_arrival_curve_empty_trace():
    dts = np.linspace(0, 5, 7)
    out = arrival_curve(np.asarray([]), dts)
    np.testing.assert_array_equal(out, np.zeros(7))


# --------------------------------------------------------- ServerStats
def test_server_stats_concurrent_record_and_read():
    stats = ServerStats()
    n_threads, per_thread = 8, 500
    stop_reading = threading.Event()

    def writer():
        for i in range(per_thread):
            stats.record(0.001 * i, i % 10 == 0)

    def reader():
        while not stop_reading.is_set():
            stats.p(99)                       # must never crash mid-append
            _ = stats.violation_rate

    readers = [threading.Thread(target=reader) for _ in range(2)]
    writers = [threading.Thread(target=writer) for _ in range(n_threads)]
    for t in readers + writers:
        t.start()
    for t in writers:
        t.join()
    stop_reading.set()
    for t in readers:
        t.join()
    assert stats.served == n_threads * per_thread
    assert stats.n_latencies == n_threads * per_thread
    assert stats.slo_violations == n_threads * (per_thread // 10)


def test_server_stats_memory_o1_at_soak_scale():
    """Regression: ``ServerStats`` kept every latency in an unbounded
    python list (O(n) memory, O(n log n) percentile reads), which made
    hours-long soaks infeasible.  At 200x a chaos soak's query count
    the latency state must stay a fixed-size histogram, with quantiles
    inside the sketch's relative-error bound and the counters, sum and
    max still EXACT."""
    stats = ServerStats()
    n = 400_000
    lats = np.random.default_rng(5).lognormal(-3.0, 1.0, size=n)
    for x in lats:
        stats.record(float(x), False)
    # pre-fix: a 400k-entry list (megabytes, one object per record);
    # post-fix: one fixed bin array regardless of n
    assert not hasattr(stats, "latencies")
    assert stats._lat_counts.nbytes <= 64 * 1024
    assert stats.n_latencies == stats.served == n
    assert stats.mean_latency == pytest.approx(float(np.mean(lats)))
    assert stats.max_latency == float(np.max(lats))
    for pct in (50, 95, 99):
        exact = float(np.percentile(lats, pct))
        assert abs(stats.p(pct) - exact) <= REL_ERR_BOUND * exact


def test_server_stats_shed_counter():
    srv = EnsembleServer(handler=lambda w: 0.0, max_queue=1)
    # not started: first submit fills the queue, second is shed
    assert srv.submit(0, {})
    assert not srv.submit(1, {})
    assert srv.stats.shed == 1


# ----------------------------------------------------------- telemetry
@pytest.mark.parametrize("exact", [True, False])
def test_telemetry_sliding_window_and_rates(exact):
    t = [0.0]
    tel = SloTelemetry(slo_seconds=0.5, window_seconds=10.0,
                       clock=lambda: t[0], exact=exact)
    for k in range(20):                       # one arrival per second
        tel.record_arrival(float(k))
        tel.record_served(0.1 if k < 18 else 0.9, float(k))
    t[0] = 20.0
    snap = tel.snapshot()
    # counts/rates are EXACT under both engines; quantiles carry the
    # sketch's histogram relative-error bound
    assert snap.n_arrivals == 9               # (10, 20] survive the window
    assert snap.arrival_rate == pytest.approx(0.9)
    assert snap.n_served == 9
    assert snap.violation_rate == pytest.approx(2 / 9)  # k=18,19 > SLO
    q_rel = 1e-6 if exact else REL_ERR_BOUND
    assert snap.p50 == pytest.approx(0.1, rel=q_rel)
    assert snap.p99 >= 0.5 * (1.0 - q_rel)


def test_telemetry_online_arrival_curve_and_tq():
    # exact=True: this pins bitwise equality against the raw-trace
    # curve/bound (the sketch's bucketed counterpart is bounded in
    # tests/test_obs.py)
    tel = SloTelemetry(window_seconds=100.0, clock=lambda: 50.0,
                       exact=True)
    rng = np.random.default_rng(0)
    arr = np.sort(rng.uniform(0, 50, 40))
    for a in arr:
        tel.record_arrival(float(a))
    dts = np.linspace(0, 10, 5)
    np.testing.assert_array_equal(tel.arrival_curve(dts),
                                  arrival_curve(arr, dts))
    assert tel.queueing_bound(mu=4.0, T0=0.05) == pytest.approx(
        queueing_bound(arr, 4.0, 0.05))
    snap = tel.snapshot(mu=4.0, ts=0.05)
    assert snap.predicted_latency == pytest.approx(
        0.05 + queueing_bound(arr, 4.0, 0.0))


def test_telemetry_memory_is_o_window_not_o_trace():
    """Regression: raw timestamps are pruned on RECORD against the
    high-water mark, so a week-long trace holds only the sliding
    window's events — the EXACT oracle's memory is O(window), not
    O(trace).  (The default sketch engine is O(1); see
    tests/test_obs.py.)"""
    tel = SloTelemetry(slo_seconds=0.5, window_seconds=10.0,
                       clock=lambda: 0.0, exact=True)
    n, rate = 50_000, 5.0            # 10_000 s of trace, 5 events/s
    for k in range(n):
        t = k / rate
        tel.record_arrival(t)
        tel.record_served(0.1, t)
        if k % 100 == 0:
            tel.record_shed(t)
    bound = int(tel.window * rate) + 2       # one window of events
    assert len(tel._arrivals) <= bound
    assert len(tel._served) <= bound
    assert len(tel._shed) <= bound
    snap = tel.snapshot(now=n / rate)
    assert snap.n_arrivals <= bound
    assert snap.arrival_rate == pytest.approx(rate, rel=0.05)
    # out-of-order feeds cannot regress the cut: a stale event lands
    # outside the (hwm - window) horizon and is REJECTED at record
    # time — it must neither linger in memory nor skew the next
    # snapshot's counts/rates
    tel.record_arrival(0.0)
    assert len(tel._arrivals) <= bound
    assert tel._arrivals[0] > n / rate - tel.window - 1.0
    assert tel.snapshot(now=n / rate).n_arrivals == snap.n_arrivals


def test_telemetry_threaded_feed():
    tel = SloTelemetry(window_seconds=60.0)
    def feed():
        for _ in range(200):
            tel.record_arrival()
            tel.record_served(0.01)
    threads = [threading.Thread(target=feed) for _ in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    snap = tel.snapshot()
    assert snap.n_arrivals == 800 and snap.n_served == 800


def test_server_telemetry_tap():
    tel = SloTelemetry(slo_seconds=1.0, window_seconds=60.0)
    srv = EnsembleServer(handler=lambda w: 1.0, n_workers=1,
                         telemetry=tel).start()
    for i in range(6):
        srv.submit(i, {})
    srv.stop()
    snap = tel.snapshot()
    assert snap.n_arrivals == 6
    assert snap.n_served == 6


# ---------------------------------------------------- ladder + facade
class _NoopLadder(SelectorLadder):
    def __init__(self, sel):
        super().__init__(sel)
        self.activations = []

    def _activate(self, selector):
        self.activations.append(selector.copy())


def _sel(n, idx):
    b = np.zeros(n, np.int8)
    b[list(idx)] = 1
    return b


def test_ladder_shed_climb_bounds():
    rungs = [_sel(6, [0]), _sel(6, [0, 1]), _sel(6, [0, 1, 2])]
    lad = _NoopLadder(rungs[2])
    lad.set_ladder(rungs)
    assert lad.ladder_pos == 2
    assert not lad.can_climb() and lad.can_shed()
    assert lad.climb() is False
    assert lad.shed() and lad.ladder_pos == 1
    assert lad.shed() and lad.ladder_pos == 0
    assert lad.shed() is False                # floor
    assert lad.climb() and lad.ladder_pos == 1
    assert len(lad.activations) == 3


def test_ladder_off_ladder_swap():
    lad = _NoopLadder(_sel(6, [0]))
    lad.set_ladder([_sel(6, [0]), _sel(6, [0, 1])])
    lad.swap_to(_sel(6, [3, 4]))              # not a rung
    assert lad.ladder_pos == -1
    assert not lad.can_shed() and not lad.can_climb()
    np.testing.assert_array_equal(lad.active_selector, _sel(6, [3, 4]))


def test_swappable_service_atomic():
    class Stub:
        def __init__(self, v):
            self.v = v

        def predict_batch(self, batch):
            return [self.v] * len(batch)

    fac = SwappableService(Stub(1.0))
    assert fac.predict_batch([{}]) == [1.0]
    old = fac.swap(Stub(2.0))
    assert old.v == 1.0
    assert fac.predict_batch([{}]) == [2.0]
    assert fac.swap_count == 1


def test_swappable_service_swap_mid_flush_stress():
    """Swaps landing mid-``predict_batch`` flush: every in-flight query
    must complete on exactly ONE service (the one its flush grabbed),
    never straddle two, and never be dropped or duplicated."""
    class TaggedService:
        def __init__(self, tag):
            self.tag = tag

        def predict_batch(self, batch):
            time.sleep(0.0005)            # a swap can land mid-flush
            return [(self.tag, q) for q in batch]

    fac = SwappableService(TaggedService(0))
    n_threads, per_thread = 4, 60
    results, lock = [], threading.Lock()

    def worker(k):
        for i in range(per_thread):
            out = fac.predict_batch([(k, i), (k, i + 10_000)])
            # both co-flushed queries retired by the SAME service
            assert out[0][0] == out[1][0]
            with lock:
                results.extend(out)

    threads = [threading.Thread(target=worker, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    n_swaps = 50
    for s in range(n_swaps):              # swap while flushes in flight
        fac.swap(TaggedService(s + 1))
        time.sleep(0.001)
    for t in threads:
        t.join()
    assert fac.swap_count == n_swaps
    # exactly once: every submitted query came back with one tag
    seen = {}
    for tag, q in results:
        assert 0 <= tag <= n_swaps
        seen.setdefault(q, []).append(tag)
    want = {(k, i + off) for k in range(n_threads)
            for i in range(per_thread) for off in (0, 10_000)}
    assert set(seen) == want
    assert all(len(tags) == 1 for tags in seen.values())


# ------------------------------------------------- hot-swap correctness
def test_hot_swap_zero_drop_and_bitwise_equal(zoo_members, rng):
    """Swapping selectors mid-stream must drop zero queries, and every
    post-swap prediction must be bitwise-equal to a cold-started
    service with the new selector."""
    n = len(zoo_members)
    sel_a = _sel(n, range(0, n, 2))
    sel_b = _sel(n, range(1, n, 2))
    swapper = HotSwapper(zoo_members, sel_a, warmup_batch_sizes=(1,))
    swapper.stage(sel_b)
    # max_batch=1 => every flush is a singleton, so server scores are
    # comparable 1:1 against cold predict_batch([w])
    srv = EnsembleServer(batch_handler=swapper.facade.predict_batch,
                         n_workers=2, max_batch=1,
                         max_wait_ms=0.5).start()
    windows = [{"ecg": rng.standard_normal((3, 250)).astype(np.float32)}
               for _ in range(24)]
    for i in range(12):
        assert srv.submit(i, windows[i])
    swapper.swap_to(sel_b)                    # mid-stream
    for i in range(12, 24):
        assert srv.submit(i, windows[i])
    stats = srv.stop()
    assert stats.served == 24                 # zero dropped
    scores = {p: s for p, s, *_ in srv.results()}
    cold = EnsembleService.for_selector(zoo_members, sel_b)
    for i in range(12, 24):
        assert scores[i] == cold.predict_batch([windows[i]])[0]


def test_hot_swap_facade_batch_bitwise(zoo_members, rng):
    """Direct facade flushes after a swap are bitwise-identical to a
    cold-started service on the same batch."""
    n = len(zoo_members)
    swapper = HotSwapper(zoo_members, _sel(n, [0, 1]),
                         warmup_batch_sizes=(1,))
    batch = [{"ecg": rng.standard_normal((3, 250)).astype(np.float32)}
             for _ in range(5)]
    swapper.swap_to(_sel(n, [2, 5, 8]))
    got = swapper.facade.predict_batch(batch)
    cold = EnsembleService.for_selector(zoo_members, _sel(n, [2, 5, 8]))
    assert got == cold.predict_batch(batch)


def test_hot_swap_staging_cached(zoo_members):
    n = len(zoo_members)
    swapper = HotSwapper(zoo_members, _sel(n, [0]),
                         warmup_batch_sizes=(1,))
    svc1 = swapper.stage(_sel(n, [1, 2]))
    svc2 = swapper.stage(_sel(n, [1, 2]))
    assert svc1 is svc2
    swapper.swap_to(_sel(n, [1, 2]))
    assert swapper.facade.current is svc1     # swap reuses the staged one


# ----------------------------------------------------- controller policy
def _snap(**kw):
    base = dict(t=0.0, window_seconds=30.0, n_arrivals=100, n_served=100,
                n_shed=0, arrival_rate=2.0, p50=0.1, p99=0.2,
                violation_rate=0.0)
    base.update(kw)
    return TelemetrySnapshot(**base)


def _controller(ladder_pos="top", **cfg):
    rungs = [_sel(4, [0]), _sel(4, [0, 1]), _sel(4, [0, 1, 2])]
    lad = _NoopLadder(rungs[-1 if ladder_pos == "top" else 0])
    lad.set_ladder(rungs)
    tel = SloTelemetry()
    conf = ControllerConfig(**{"slo_seconds": 1.0, "cooldown_seconds": 0.0,
                               **cfg})
    return AdaptiveController(tel, lad, config=conf, sync=True), lad


def test_decide_holds_without_samples():
    ctl, _ = _controller()
    assert ctl.decide(_snap(n_served=3)) is Decision.HOLD


def test_decide_sheds_on_violations():
    ctl, _ = _controller()
    assert ctl.decide(_snap(violation_rate=0.5)) is Decision.SHED
    assert ctl.decide(_snap(p99=1.4)) is Decision.SHED
    assert ctl.decide(_snap(n_shed=5)) is Decision.SHED


def test_decide_recomposes_when_cannot_shed():
    ctl, _ = _controller(ladder_pos="bottom")
    assert ctl.decide(_snap(violation_rate=0.5)) is Decision.RECOMPOSE


def test_decide_recomposes_on_drift_and_predicted_risk():
    ctl, _ = _controller()
    ctl.baseline_rate = 2.0
    assert ctl.decide(_snap(arrival_rate=4.0)) is Decision.RECOMPOSE
    assert ctl.decide(_snap(arrival_rate=1.0)) is Decision.RECOMPOSE
    ctl.baseline_rate = None
    assert ctl.decide(_snap(ts=0.3, tq_bound=1.1)) is Decision.RECOMPOSE


def test_replace_triggers_once_until_placement_changes():
    """An unimprovable plan (re_place returns False) must not be
    re-tried every step — that would re-measure costs forever and
    starve the recompose/climb branches below REPLACE."""
    from repro.serving.placement import Placement

    class ReplaceLadder(_NoopLadder):
        def __init__(self, sel):
            super().__init__(sel)
            self.active_placement = Placement([[0], [1, 2]], [1.0, 2.0])
            self.re_place_calls = 0

        def re_place(self, placement=None):
            self.re_place_calls += 1
            return False                  # LPT cannot do better

    lad = ReplaceLadder(_sel(4, [0, 1, 2]))
    lad.set_ladder([_sel(4, [0]), _sel(4, [0, 1, 2])])
    tel = SloTelemetry(slo_seconds=1.0, window_seconds=30.0,
                       clock=lambda: 100.0)
    for k in range(30):
        tel.record_arrival(80.0 + k / 2)
        tel.record_served(0.1, 80.0 + k / 2)
    ctl = AdaptiveController(
        tel, lad,
        config=ControllerConfig(slo_seconds=1.0, cooldown_seconds=0.0,
                                imbalance_high=1.25),
        service_profile_fn=lambda: (50.0, 0.05, 2.0),   # imbalanced
        sync=True, clock=lambda: 100.0)
    assert ctl.decide(ctl.snapshot()) is Decision.REPLACE
    assert ctl.step() is Decision.HOLD    # re_place no-op: no action
    assert lad.re_place_calls == 1
    assert ctl.step() is Decision.HOLD    # guard: not re-tried
    assert lad.re_place_calls == 1
    # the placement changed some other way: REPLACE is eligible again
    lad.active_placement = Placement([[0, 1], [2]], [2.0, 1.0])
    assert ctl.decide(ctl.snapshot()) is Decision.REPLACE


def test_controller_async_replace_does_not_block_step():
    """sync=False: the expensive measure+stage of a RE-PLACE runs in a
    background thread — step() returns immediately and the monitor
    stays free to act while the rebalance is in flight."""
    from repro.serving.placement import Placement

    class SlowReplaceLadder(_NoopLadder):
        def __init__(self, sel):
            super().__init__(sel)
            self.active_placement = Placement([[0], [1, 2]], [1.0, 3.0])
            self.release = threading.Event()

        def re_place(self, placement=None):
            self.release.wait(2.0)        # a slow cost measurement
            self.active_placement = Placement([[0, 2], [1]], [2.0, 2.0])
            return True

    lad = SlowReplaceLadder(_sel(4, [0, 1, 2]))
    lad.set_ladder([_sel(4, [0]), _sel(4, [0, 1, 2])])
    t = [100.0]
    tel = SloTelemetry(slo_seconds=1.0, window_seconds=30.0,
                       clock=lambda: t[0])
    for k in range(30):
        tel.record_arrival(80.0 + k / 2)
        tel.record_served(0.1, 80.0 + k / 2)
    ctl = AdaptiveController(
        tel, lad,
        config=ControllerConfig(slo_seconds=1.0, cooldown_seconds=0.0),
        service_profile_fn=lambda: (50.0, 0.05, 3.0),
        sync=False, clock=lambda: t[0])
    t0 = time.monotonic()
    assert ctl.step() is Decision.REPLACE
    assert time.monotonic() - t0 < 0.5    # did not wait on re_place
    assert ctl._replacing.is_set()
    assert ctl.step() is Decision.HOLD    # one rebalance in flight
    lad.release.set()
    ctl._replace_thread.join(5.0)
    assert lad.active_placement.loads == [2.0, 2.0]
    assert ctl._replace_noop_sig is None  # it acted: no no-op brand


def test_decide_climbs_only_with_headroom():
    ctl, _ = _controller(ladder_pos="bottom")
    assert ctl.decide(_snap(p99=0.2)) is Decision.CLIMB
    assert ctl.decide(_snap(p99=0.8)) is Decision.HOLD     # no headroom
    ctl_top, _ = _controller(ladder_pos="top")
    assert ctl_top.decide(_snap(p99=0.2)) is Decision.HOLD  # at the top


def test_controller_step_acts_and_cools_down():
    calls = []
    rungs = [_sel(4, [0]), _sel(4, [0, 1, 2])]
    lad = _NoopLadder(rungs[-1])
    lad.set_ladder(rungs)
    t = [100.0]
    tel = SloTelemetry(slo_seconds=1.0, window_seconds=30.0,
                      clock=lambda: t[0])
    for k in range(40):
        tel.record_arrival(80.0 + k / 2)
        tel.record_served(2.0, 80.0 + k / 2)  # everything violates
    ctl = AdaptiveController(
        tel, lad, recompose_fn=lambda s: calls.append(s) or rungs[0],
        config=ControllerConfig(slo_seconds=1.0, cooldown_seconds=30.0),
        sync=True, clock=lambda: t[0])
    assert ctl.step() is Decision.SHED
    assert lad.ladder_pos == 0                # shed to the cheap rung
    assert len(calls) == 1                    # recompose kicked off too
    assert ctl.step() is Decision.HOLD        # cooldown gates the next one
    t[0] = 140.0
    for k in range(60):                       # healthy, same 2/s rate as
        tel.record_served(0.1, 120.0 + k / 3)  # the baseline (no drift)
        tel.record_arrival(120.0 + k / 3)
    assert ctl.step() is Decision.CLIMB
    assert lad.ladder_pos == 1


def test_controller_async_recompose_swaps():
    rungs = [_sel(4, [0]), _sel(4, [0, 1])]
    lad = _NoopLadder(rungs[1])
    lad.set_ladder(rungs)
    tel = SloTelemetry(slo_seconds=1.0, window_seconds=30.0)
    done = threading.Event()

    def slow_recompose(snap):
        done.wait(2.0)
        return _sel(4, [2, 3])

    ctl = AdaptiveController(tel, lad, recompose_fn=slow_recompose,
                             config=ControllerConfig(cooldown_seconds=0.0,
                                                     min_samples=0),
                             sync=False)
    ctl.baseline_rate = 1.0
    now = time.monotonic()
    for k in range(30):
        tel.record_arrival(now - k * 0.1)
    ctl.step()                                # drift -> async recompose
    assert ctl._recomposing.is_set()
    done.set()
    ctl.join_recompose(5.0)
    np.testing.assert_array_equal(lad.active_selector, _sel(4, [2, 3]))
    assert ctl.n_recomposes == 1


def test_controller_stop_joins_all_threads():
    """Satellite regression: stop() must actually wait for the monitor
    AND any in-flight recompose, report success, and leave no
    ``repro-ctl-*`` thread running."""
    ctl, _ = _controller()
    ctl.sync = False
    ctl.start(period_seconds=0.02)
    time.sleep(0.1)                      # a few monitor ticks
    assert ctl.stop(timeout=5.0) is True
    assert ctl.leaked == []
    assert not any(t.name.startswith("repro-ctl-")
                   for t in threading.enumerate() if t.is_alive())


def test_controller_stop_reports_hung_recompose():
    rungs = [_sel(4, [0]), _sel(4, [0, 1])]
    lad = _NoopLadder(rungs[1])
    lad.set_ladder(rungs)
    tel = SloTelemetry(slo_seconds=1.0, window_seconds=30.0)
    hang = threading.Event()

    def hung_recompose(snap):
        hang.wait(10.0)
        return None

    ctl = AdaptiveController(tel, lad, recompose_fn=hung_recompose,
                             config=ControllerConfig(cooldown_seconds=0.0,
                                                     min_samples=0),
                             sync=False)
    ctl.baseline_rate = 1.0
    now = time.monotonic()
    for k in range(30):
        tel.record_arrival(now - k * 0.1)
    ctl.step()                           # drift -> async recompose hangs
    assert ctl.stop(timeout=0.2) is False
    assert "repro-ctl-recompose" in ctl.leaked
    hang.set()                           # let the daemon thread exit
    ctl.join_recompose(5.0)


# ----------------------------------------------------------- recompose
def test_recompose_warm_start_reuses_accuracy():
    n, f_a, f_l, lat, _, _ = make_testbed(seed=1)
    res0 = compose(n, f_a, f_l, 0.2,
                   ComposerParams(N=6, M=60, K=4, N0=8, seed=1))
    new_calls = [0]

    def f_a_counting(b):
        new_calls[0] += 1
        return f_a(b)

    def f_l_doubled(b):                       # load doubled: 2x latency
        return 2.0 * f_l(b)

    res1 = recompose(f_a_counting, f_l_doubled, 0.2, warm_start=res0,
                     params=ComposerParams(N=4, M=60, K=4, N0=8, seed=1))
    assert res1.feasible
    assert res1.latency <= 0.2 + 1e-9
    assert f_l_doubled(res1.b_star) == pytest.approx(res1.latency)
    # the memo table absorbed previously profiled selectors: strictly
    # fewer fresh accuracy calls than profiler calls
    assert new_calls[0] < res1.n_profiler_calls
    assert res1.accuracy > 0.5


def test_recompose_keeps_incumbent_when_still_optimal():
    n, f_a, f_l, *_ = make_testbed(seed=2)
    res0 = compose(n, f_a, f_l, 0.2,
                   ComposerParams(N=8, M=80, K=6, N0=10, seed=2))
    res1 = recompose(f_a, f_l, 0.2, warm_start=res0,
                     params=ComposerParams(N=3, M=60, K=4, N0=8, seed=2))
    # same load, same budget: the incumbent is a seed, so the result
    # can only match or beat it
    assert res1.accuracy >= res0.accuracy - 1e-9


# ------------------------------------------------------ simulator churn
def test_churn_deterministic_under_seed():
    cfg = SimConfig(window_seconds=10.0, duration_seconds=80.0,
                    census=[(0.0, 8), (40.0, 16), (60.0, 4)], seed=5)
    r1, r2 = simulate([0.01], cfg), simulate([0.01], cfg)
    np.testing.assert_array_equal(r1.arrivals, r2.arrivals)
    assert r1.churn_log == r2.churn_log


def test_churn_conserves_query_counts():
    cfg = SimConfig(window_seconds=10.0, duration_seconds=100.0,
                    census=[(0.0, 10), (30.0, 25), (70.0, 5)], seed=7)
    r = simulate([0.01], cfg)
    counts = {}
    for q in r.queries:
        counts[q.patient] = counts.get(q.patient, 0) + 1
    total = 0
    for p, (t_a, t_d, ph) in r.patients.items():
        exp, k = 0, 1
        while True:
            t = t_a + ph + k * cfg.window_seconds
            if t > cfg.duration_seconds or t >= t_d:
                break
            exp, k = exp + 1, k + 1
        assert counts.get(p, 0) == exp
        total += exp
    assert total == len(r.arrivals) == len(r.queries)


def test_churn_census_step_scales_arrival_rate():
    cfg = SimConfig(window_seconds=10.0, duration_seconds=120.0,
                    census=[(0.0, 10), (60.0, 30)], seed=3)
    r = simulate([0.005], cfg)
    first = np.sum((r.arrivals >= 20) & (r.arrivals < 60))
    second = np.sum(r.arrivals >= 80)
    # 3x census => ~3x arrivals per unit time (same 40 s spans)
    assert second > 2 * first


def test_churn_burst_admissions_synchronized():
    cfg = SimConfig(window_seconds=10.0, duration_seconds=40.0,
                    census=[(0.0, 6)], churn_phase_jitter=0.0, seed=0)
    r = simulate([0.002], cfg)
    _, cnt = np.unique(r.arrivals, return_counts=True)
    assert cnt.max() == 6                     # thundering herd


def test_default_path_has_no_churn_bookkeeping():
    r = simulate([0.01], SimConfig(n_patients=4, duration_seconds=40.0,
                                   window_seconds=10.0))
    assert r.patients == {} and r.churn_log == []


# --------------------------------------------- backlog carry-over (DES)
def test_backlog_conserved_at_epoch_edge():
    """carry_backlog epoch cut: every born query is either retired this
    epoch or carried out — none dropped, none double-counted."""
    cfg = SimConfig(n_patients=30, n_devices=1, window_seconds=5.0,
                    duration_seconds=40.0, seed=0, carry_backlog=True)
    r1 = simulate([0.3], cfg)             # overloaded: backlog builds
    assert len(r1.backlog) > 0
    assert len(r1.queries) + len(r1.backlog) == len(r1.arrivals)
    # backlog ages are within the epoch and oldest-first
    assert np.all(r1.backlog > 0) and np.all(r1.backlog <= 40.0)
    assert np.all(np.diff(r1.backlog) <= 0)

    # next epoch ingests the carry: each carried query is served exactly
    # once (or carried again), with latency that spans the epoch edge
    r2 = simulate([0.05], cfg, backlog=r1.backlog)
    from_backlog = [q for q in r2.queries if q.t_window < 0]
    carried_again = int(np.sum(r2.backlog > cfg.duration_seconds)) \
        if len(r2.backlog) else 0
    assert len(from_backlog) + carried_again == len(r1.backlog)
    assert all(q.latency > 0 for q in from_backlog)
    ages = sorted(-q.t_window for q in from_backlog)
    assert ages == sorted(a for a in r1.backlog)[:len(ages)]


def test_backlog_drain_mode_unchanged():
    """carry_backlog=False keeps the original drain-to-empty semantics:
    no backlog, every query retired in its own epoch."""
    cfg = SimConfig(n_patients=30, n_devices=1, window_seconds=5.0,
                    duration_seconds=40.0, seed=0)
    r = simulate([0.3], cfg)
    assert len(r.backlog) == 0
    assert len(r.queries) == len(r.arrivals)


def test_adaptive_bench_conserves_queries_across_epochs():
    """Regression for the epoch-edge accounting in the adaptive bench:
    total born == total served + final backlog, per arm."""
    from benchmarks.adaptive_bench import run_adaptive_sim, \
        synthetic_testbed
    zoo, costs, f_a = synthetic_testbed(seed=0)
    common = dict(zoo=zoo, costs=costs, f_a=f_a, slo=1.0,
                  schedule=[(2, 24), (2, 72), (2, 24)], seed=0)
    for adaptive in (False, True):
        out = run_adaptive_sim(adaptive=adaptive, **common)
        assert out["born_total"] \
            == out["served_total"] + out["final_backlog"]
        for rec in out["epochs"]:
            assert rec["served"] + rec["backlog_out"] \
                == rec["born"] + rec["backlog_in"]
        # the static arm under sustained overload actually carries work
        if not adaptive:
            assert any(rec["backlog_out"] > 0 for rec in out["epochs"])


def test_tiered_bench_conserves_and_protects_critical():
    """Regression for the BENCH tiered section: per-tier conservation
    fields sum to the fleet totals, every epoch's rungs honor the
    shed-order invariant, and only low-acuity rungs absorb the shed
    while the critical tier holds the rich ensemble."""
    from benchmarks.adaptive_bench import run_tiered_sim, \
        synthetic_testbed
    zoo, costs, f_a = synthetic_testbed(seed=0)
    out = run_tiered_sim(zoo=zoo, costs=costs, f_a=f_a, slo=1.0,
                         schedule=[(2, 24), (3, 72), (2, 24)], seed=0)
    assert out["per_tier_served_sum"] == out["served_total"]
    assert out["born_total"] == out["served_total"] \
        + out["final_backlog"]
    tiers = list(out["tier_fracs"])
    top_rung = len(out["ladder_sizes"]) - 1
    for rec in out["epochs"]:
        rungs = [rec["tiers"][t]["rung"] for t in tiers]
        assert all(a <= b for a, b in zip(rungs, rungs[1:]))
        for t in tiers:
            tr = rec["tiers"][t]
            assert tr["served"] + tr["backlog_out"] \
                == tr["born"] + tr["backlog_in"]
    crit, stable = tiers[-1], tiers[0]
    assert out["per_tier"][crit]["min_rung"] == top_rung  # held rich
    assert out["per_tier"][stable]["min_rung"] < top_rung  # absorbed


# ------------------------------------------------- adaptive end-to-end
def test_adaptive_beats_static_under_spike():
    """Acceptance: under a census spike the controller recomposes/sheds
    and keeps p99 under the SLO where the static ensemble violates."""
    from benchmarks.adaptive_bench import run_adaptive_sim, \
        synthetic_testbed
    zoo, costs, f_a = synthetic_testbed(seed=0)
    common = dict(zoo=zoo, costs=costs, f_a=f_a, slo=1.0,
                  schedule=[(2, 24), (3, 72)], seed=0)
    static = run_adaptive_sim(adaptive=False, **common)
    adaptive = run_adaptive_sim(adaptive=True, **common)
    assert static["epochs"][-1]["p99_s"] > 1.0         # static violates
    assert adaptive["epochs"][-1]["p99_s"] <= 1.0      # adaptive doesn't
    assert adaptive["violation_rate"] < static["violation_rate"]
    assert len(adaptive["actions"]) >= 1
