"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.conv1d_stripe import (conv1d_stripe,
                                         conv1d_stripe_stacked)
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.ssd_scan import ssd

KEY = jax.random.PRNGKey(0)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,S,T,Hq,Hkv,D,causal,window", [
    (2, 64, 64, 4, 2, 32, True, 0),
    (1, 128, 128, 8, 8, 64, True, 16),
    (2, 48, 96, 4, 1, 32, True, 0),
    (1, 64, 64, 2, 2, 32, False, 0),
    (1, 33, 70, 6, 3, 16, True, 24),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, T, Hq, Hkv, D, causal, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), dtype)
    qpos = jnp.arange(T - S, T)
    kpos = jnp.arange(T)
    want = ref.attention(q, k, v, qpos, kpos, causal=causal, window=window)
    got = flash_attention(q, k, v, qpos, kpos, causal=causal,
                          window=window, block_q=32, block_k=32,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("B,T,Hq,Hkv,D,window,fill", [
    (2, 128, 8, 2, 64, 0, 128),
    (2, 128, 8, 2, 64, 0, 100),     # partially-filled cache
    (1, 96, 4, 4, 32, 32, 96),      # windowed ring
    (2, 80, 4, 1, 32, 0, 80),       # MQA, unaligned length
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, T, Hq, Hkv, D, window, fill, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), dtype)
    kpos = jnp.where(jnp.arange(T) < fill, jnp.arange(T), -1)
    qpos = jnp.asarray(fill)
    want = ref.decode_attention(q, k, v, kpos, qpos, window=window)
    got = decode_attention(q, k, v, kpos, qpos, window=window,
                           block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (2, 64, 4, 16, 2, 8, 16),
    (1, 48, 4, 8, 1, 16, 16),
    (2, 32, 2, 16, 2, 8, 8),
    (1, 40, 4, 8, 4, 8, 16),        # padded chunk
])
def test_ssd(B, S, H, P, G, N, chunk):
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    D = jnp.ones((H,))
    h0 = jax.random.normal(ks[5], (B, H, P, N)) * 0.1
    yw, hw = ref.ssd_chunked(x, dt, A, Bm, Cm, D, chunk, h0)
    yg, hg = ssd(x, dt, A, Bm, Cm, D, chunk, h0, interpret=True)
    np.testing.assert_allclose(yg, yw, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(hg, hw, rtol=1e-4, atol=1e-4)


def test_ssd_matches_sequential_decode():
    """Chunked prefill state == running the recurrent step S times."""
    B, S, H, P, G, N = 1, 32, 2, 8, 1, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    D = jnp.zeros((H,))
    y_chunk, hT = ref.ssd_chunked(x, dt, A, Bm, Cm, D, 8)
    h = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        y, h = ref.ssd_decode_step(h, x[:, t], dt[:, t], A, Bm[:, t],
                                   Cm[:, t], D)
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_chunk, y_seq, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(hT, h, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("E,C,d,f", [(4, 64, 32, 48), (2, 100, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm(E, C, d, f, dtype):
    ks = jax.random.split(KEY, 4)
    xb = jax.random.normal(ks[0], (E, C, d), dtype)
    wg = (jax.random.normal(ks[1], (E, d, f)) / d ** 0.5).astype(dtype)
    wu = (jax.random.normal(ks[2], (E, d, f)) / d ** 0.5).astype(dtype)
    wd = (jax.random.normal(ks[3], (E, f, d)) / f ** 0.5).astype(dtype)
    want = ref.moe_gmm(xb, wg, wu, wd)
    got = moe_gmm(xb, wg, wu, wd, block_c=32, block_f=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("B,L,Cin,Cout,K,stride,groups,pad", [
    (2, 64, 8, 16, 7, 1, 1, "SAME"),
    (2, 64, 8, 16, 7, 2, 1, "SAME"),
    (1, 50, 12, 12, 4, 1, 12, "CAUSAL"),
    (2, 33, 8, 8, 7, 2, 4, "SAME"),
])
def test_conv1d_stripe(B, L, Cin, Cout, K, stride, groups, pad):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (B, L, Cin))
    w = jax.random.normal(ks[1], (K, Cin // groups, Cout))
    want = ref.conv1d_stripe(x, w, None, stride, groups, pad)
    got = conv1d_stripe(x, w, None, stride, groups, pad, interpret=True)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("M,B,L,Cin,Cout,K,stride,groups,pad", [
    (3, 2, 64, 8, 16, 7, 1, 1, "SAME"),
    (2, 2, 64, 8, 16, 7, 2, 1, "SAME"),     # strided
    (4, 1, 50, 12, 12, 4, 1, 12, "CAUSAL"),  # depthwise, odd length
    (2, 2, 33, 8, 8, 7, 2, 4, "SAME"),      # grouped, odd length
    (5, 3, 41, 4, 8, 7, 2, 2, "SAME"),      # odd length + stride
])
def test_conv1d_stripe_stacked(M, B, L, Cin, Cout, K, stride, groups, pad):
    """Member-axis kernel (grid (member, batch, groups)) vs a vmapped
    oracle — the fused ensemble bucket's conv path."""
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (M, B, L, Cin))
    w = jax.random.normal(ks[1], (M, K, Cin // groups, Cout))
    b = jax.random.normal(ks[2], (M, Cout))
    want = jax.vmap(lambda xm, wm, bm: ref.conv1d_stripe(
        xm, wm, bm, stride, groups, pad))(x, w, b)
    got = conv1d_stripe_stacked(x, w, b, stride, groups, pad,
                                interpret=True)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_ops_conv1d_stacked_dispatch():
    """ops.conv1d routes 4-D member-stacked inputs to the stacked paths
    and keeps xla / pallas_interpret numerics aligned."""
    from repro.kernels import ops
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (3, 2, 40, 8))
    w = jax.random.normal(ks[1], (3, 7, 2, 8))
    b = jax.random.normal(ks[2], (3, 8))
    want = ops.conv1d(x, w, b, stride=2, groups=4, impl="xla")
    got = ops.conv1d(x, w, b, stride=2, groups=4,
                     impl="pallas_interpret")
    assert want.shape == (3, 2, 20, 8)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
