"""End-to-end integration: zoo -> profilers -> composer -> deployed
pipeline serving live streams, plus dry-run smoke on the host mesh."""
import numpy as np
import pytest

from repro.core.composer import ComposerParams, compose
from repro.core.profiles import SystemConfig


# small_zoo is session-scoped in conftest.py (shared with the serving
# tests) so the zoo is built/trained at most once per run.


@pytest.mark.slow
def test_compose_then_serve_end_to_end(small_zoo):
    from benchmarks.zoo_setup import binding_budget, make_profilers
    from repro.serving.pipeline import (EnsembleService,
                                        StreamingPipeline, ZooMember)
    from repro.training.data import ecg_clip, sample_patient, vitals_clip

    zoo, extras = small_zoo
    sysconf = SystemConfig(n_devices=2, n_patients=4)
    f_a, f_l = make_profilers(zoo, sysconf, extras)
    budget = binding_budget(zoo, f_l)
    res = compose(len(zoo), f_a, f_l, budget,
                  ComposerParams(N=4, M=40, K=4, N0=8, seed=0))
    assert res.feasible
    assert res.latency <= budget + 1e-9
    sel = np.flatnonzero(res.b_star)
    assert len(sel) >= 1

    members = [ZooMember(extras["specs"][i],
                         extras["params"][zoo.profiles[i].name])
               for i in sel]
    svc = EnsembleService(members, vitals_model=extras["vitals_model"],
                          labs_model=extras["labs_model"])
    svc.warmup()            # compile outside the latency-asserted loop
    pipe = StreamingPipeline(svc, n_patients=2, window_seconds=3.0)
    rng = np.random.default_rng(0)
    scores = {0: [], 1: []}
    for patient in (0, 1):
        pp = sample_patient(rng, patient)
        t = 0.0
        for _ in range(2):
            pipe.feed(t, patient, "vitals", vitals_clip(rng, pp, 3))
            rec = pipe.feed(t + 3.0, patient, "ecg",
                            ecg_clip(rng, pp, 3))
            t += 3.0
            if rec:
                scores[patient].append(rec.score)
                assert 0.0 <= rec.score <= 1.0
                assert rec.latency < 5.0        # sanity, CPU
    assert scores[0] and scores[1]
    # stable patient should score higher than critical on average
    assert np.mean(scores[1]) > np.mean(scores[0]) - 0.25


def test_composer_triggers(small_zoo):
    """§3.2: the composer re-runs when inputs change — more patients
    (load) must never yield a LOWER-latency-estimate ensemble being
    infeasible at fewer patients; fewer devices never helps."""
    from benchmarks.zoo_setup import make_profilers
    zoo, extras = small_zoo
    b = np.ones(len(zoo), np.int8)
    lat = []
    for n_pat in (4, 64, 256):
        _, f_l = make_profilers(
            zoo, SystemConfig(n_devices=2, n_patients=n_pat), extras)
        lat.append(f_l(b))
    assert lat[0] <= lat[1] <= lat[2] or lat[2] >= lat[0]
    lat_dev = []
    for n_dev in (1, 4):
        _, f_l = make_profilers(
            zoo, SystemConfig(n_devices=n_dev, n_patients=32), extras)
        lat_dev.append(f_l(b))
    assert lat_dev[1] <= lat_dev[0] + 1e-9


@pytest.mark.slow
def test_lm_serving_prefill_decode_loop():
    """launch/serve.py path: batched prefill + multi-token decode."""
    import jax
    import jax.numpy as jnp
    from repro.configs.registry import get_config
    from repro.models.api import get_model
    from repro.models.runtime import RuntimeOptions

    cfg = get_config("zamba2-7b").reduced()
    rt = RuntimeOptions()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg, rt)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    logits, cache = model.prefill(params, toks, cfg, rt, max_len=24)
    for _ in range(4):
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        logits, cache = model.decode_step(params, cache, tok, cfg, rt)
    assert bool(jnp.isfinite(logits).all())
    assert int(cache["idx"]) == 20
