"""Per-assigned-architecture smoke tests: REDUCED variant (<=2 layers,
d_model<=512, <=4 experts), one forward + one train step on CPU, asserting
output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.models.api import get_model
from repro.models.runtime import RuntimeOptions
from repro.training.optimizer import AdamW, constant_schedule
from repro.training.train_loop import make_train_step

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    total = S + (cfg.n_prefix_tokens if cfg.family == "vlm" else 0)
    batch = {"tokens": toks,
             "labels": jax.random.randint(KEY, (B, total), 0,
                                          cfg.vocab_size)}
    if cfg.n_prefix_tokens and cfg.frontend_dim:
        batch["prefix_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_prefix_tokens, cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward(arch):
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 512 and cfg.num_layers <= max(
        2, 2 * (cfg.shared_attn_every or 1))
    if cfg.moe:
        assert cfg.moe.n_routed_experts <= 4
    rt = RuntimeOptions()
    model = get_model(cfg)
    params = model.init(KEY, cfg, rt)
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch["tokens"], cfg, rt,
                                prefix_embeds=batch.get("prefix_embeds"))
    total = S + (cfg.n_prefix_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, total, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    rt = RuntimeOptions()
    model = get_model(cfg)
    params = model.init(KEY, cfg, rt)
    opt = AdamW(lr=constant_schedule(1e-3))
    step = jax.jit(make_train_step(cfg, rt, opt))
    opt_state = opt.init(params)
    batch = _batch(cfg)
    new_params, opt_state, loss = step(params, opt_state, batch)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_decode_consistency(arch):
    """Cached decode == teacher-forced forward (capacity relaxed for MoE:
    per-token routing must match the full-sequence pass)."""
    cfg = get_config(arch).reduced()
    rt = RuntimeOptions(capacity_factor=16.0)
    model = get_model(cfg)
    params = model.init(KEY, cfg, rt)
    toks = jax.random.randint(KEY, (B, S + 2), 0, cfg.vocab_size)
    pe = None
    if cfg.n_prefix_tokens and cfg.frontend_dim:
        pe = jax.random.normal(KEY, (B, cfg.n_prefix_tokens,
                                     cfg.frontend_dim))
    full, _ = model.forward(params, toks, cfg, rt, prefix_embeds=pe)
    off = cfg.n_prefix_tokens if cfg.family == "vlm" else 0
    lg, cache = model.prefill(params, toks[:, :S], cfg, rt,
                              prefix_embeds=pe)
    np.testing.assert_allclose(lg, full[:, off + S - 1], rtol=2e-3,
                               atol=2e-3)
    for t in range(2):
        lg, cache = model.decode_step(params, cache, toks[:, S + t],
                                      cfg, rt)
        np.testing.assert_allclose(lg, full[:, off + S + t], rtol=2e-3,
                                   atol=2e-3)


def test_kv_mult_invariance():
    """Duplicating KV heads for sharding must not change numerics."""
    cfg = get_config("granite-20b").reduced()
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    outs = []
    for mult in (1, 4):
        rt = RuntimeOptions(kv_mult=mult)
        params = get_model(cfg).init(KEY, cfg, rt)
        if mult > 1:
            # same logical weights: tile the kv projections
            p1 = outs[0][1]
            params = jax.tree.map(lambda a: a, p1)

            def tile(seg):
                for blk in ("wk", "wv"):
                    seg["attn"][blk]["w"] = jnp.concatenate(
                        [seg["attn"][blk]["w"]] * mult, axis=-1)
                    if "b" in seg["attn"][blk]:
                        seg["attn"][blk]["b"] = jnp.concatenate(
                            [seg["attn"][blk]["b"]] * mult, axis=-1)
                return seg
            params["segments"][0] = tile(params["segments"][0])
        logits, _ = get_model(cfg).forward(params, toks, cfg, rt)
        outs.append((logits, params))
    np.testing.assert_allclose(outs[0][0], outs[1][0], rtol=2e-3,
                               atol=2e-3)


def test_sliding_window_matches_full_for_short_seq():
    """window >= S must equal full attention."""
    cfg = get_config("qwen3-4b").reduced()
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    model = get_model(cfg)
    params = model.init(KEY, cfg, RuntimeOptions())
    full, _ = model.forward(params, toks, cfg, RuntimeOptions())
    win, _ = model.forward(params, toks, cfg, RuntimeOptions(window=S))
    np.testing.assert_allclose(full, win, rtol=1e-5, atol=1e-5)
