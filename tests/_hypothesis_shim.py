"""Minimal fallback for ``hypothesis`` so the property tests still run
(as seeded random sampling) on machines without the package installed.

Usage in test modules:

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from _hypothesis_shim import given, settings, st

Only the strategy surface the test-suite uses is implemented:
``integers, floats, booleans, sampled_from, lists``.  ``given`` draws
``max_examples`` (default 20) pseudo-random examples from a fixed seed so
runs are deterministic; ``settings`` records ``max_examples`` and ignores
everything else (``deadline`` etc.).
"""
from __future__ import annotations

import random
from typing import Any, Callable, List

_DEFAULT_MAX_EXAMPLES = 20
_SEED = 0x480  # fixed; determinism matters, the value doesn't


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example(self, rng: random.Random) -> Any:
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def sampled_from(seq) -> _Strategy:
    items = list(seq)
    return _Strategy(lambda rng: rng.choice(items))


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(rng: random.Random) -> List[Any]:
        n = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(n)]
    return _Strategy(draw)


class _St:
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    booleans = staticmethod(booleans)
    sampled_from = staticmethod(sampled_from)
    lists = staticmethod(lists)


st = _St()


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*strategies: _Strategy):
    # NOTE: the wrapper must expose a ZERO-ARG signature (no
    # functools.wraps) or pytest treats the drawn-parameter names as
    # missing fixtures.
    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_shim_max_examples",
                        _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(_SEED)
            for _ in range(n):
                drawn = [s.example(rng) for s in strategies]
                fn(*drawn)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        # settings() may be applied above or below @given
        wrapper._shim_max_examples = getattr(
            fn, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)
        return wrapper
    return deco
