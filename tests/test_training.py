"""Training substrate: optimizer, data, checkpointing, loss curves."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.ecg_zoo import zoo_specs
from repro.configs.registry import get_config
from repro.models.layers import softmax_xent
from repro.models.runtime import RuntimeOptions
from repro.training import checkpoint
from repro.training.data import (lm_batches, make_icu_dataset,
                                 split_by_patient)
from repro.training.optimizer import (AdamW, constant_schedule,
                                      cosine_schedule, global_norm)
from repro.training.train_loop import (ecg_predict_proba, train_ecg_model,
                                       train_lm)


def test_adamw_reduces_quadratic():
    opt = AdamW(lr=constant_schedule(0.1), weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_grad_clip():
    opt = AdamW(lr=constant_schedule(0.1), grad_clip=1.0)
    g = {"a": jnp.full((4,), 100.0)}
    assert float(global_norm(g)) == pytest.approx(200.0)
    params = {"a": jnp.zeros((4,))}
    state = opt.init(params)
    p2, _ = opt.update(g, state, params)
    assert bool(jnp.isfinite(p2["a"]).all())


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.1)


def test_softmax_xent_masking():
    logits = jnp.asarray([[[2.0, 0.0], [0.0, 2.0]]])
    labels = jnp.asarray([[0, -1]])           # second token masked
    l1 = softmax_xent(logits, labels)
    l2 = softmax_xent(logits[:, :1], labels[:, :1])
    assert float(l1) == pytest.approx(float(l2))


def test_lm_loss_decreases():
    cfg = get_config("smollm-360m").reduced()
    _, losses = train_lm(cfg, RuntimeOptions(),
                         lm_batches(cfg.vocab_size, 8, 64, seed=0),
                         steps=25)
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_icu_dataset_structure():
    data = make_icu_dataset(n_patients=4, clips_per_patient=3, seed=0,
                            seconds=2)
    assert data["ecg"].shape == (12, 3, 500)
    assert data["vitals"].shape == (12, 7, 2)
    assert data["labs"].shape == (12, 8)
    tr, va = split_by_patient(data, holdout=1)
    assert set(np.unique(va["patient"])) == {3}
    assert not set(np.unique(tr["patient"])) & {3}


def test_ecg_model_learns(icu_data):
    tr, va = icu_data
    spec = zoo_specs(reduced=True, input_len=750)[0]
    params, losses = train_ecg_model(spec, tr["ecg"][:, 0, :],
                                     tr["label"], steps=60, seed=0)
    assert losses[-1] < losses[0]
    proba = ecg_predict_proba(params, va["ecg"][:, 0, :], spec)
    assert proba.shape == (len(va["label"]),)
    assert np.all((proba >= 0) & (proba <= 1))


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
            "c": [jnp.ones((4,)), jnp.zeros((2, 2))]}
    path = os.path.join(tmp_path, "ck.npz")
    checkpoint.save(path, tree, {"step": 7})
    out = checkpoint.restore(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_allclose(a, b)
    assert checkpoint.load_metadata(path)["step"] == 7


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "ck.npz")
    checkpoint.save(path, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        checkpoint.restore(path, {"w": jnp.zeros((3, 3))})


def test_random_forest_and_logreg():
    from repro.core.forest import RandomForest
    from repro.models.tabular import LogisticRegression
    rng = np.random.default_rng(0)
    X = rng.normal(0, 1, (300, 8))
    y_reg = X[:, 0] * 2 + X[:, 1] + 0.1 * rng.normal(size=300)
    rf = RandomForest(n_trees=20, max_depth=8).fit(X[:200], y_reg[:200])
    assert rf.score_r2(X[200:], y_reg[200:]) > 0.5
    y_cls = (X @ rng.normal(0, 1, 8) > 0).astype(float)
    lr = LogisticRegression(steps=300).fit(X[:200], y_cls[:200])
    acc = np.mean((lr.predict_proba(X[200:]) > 0.5) == y_cls[200:])
    assert acc > 0.8
