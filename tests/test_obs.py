"""Observability plane: windowed-sketch telemetry vs the exact deque
oracle, per-query span tracing, and the Prometheus/JSONL export layer.

The sketch's contract (obs/sketch.py) is precise, so these tests gate
it precisely: event counts and violation rate EXACT, quantiles within
the log-histogram's relative-error bound, T_q within one sub-window
bucket, merges associative with the flat feed — on randomized
out-of-order traces, not hand-picked ones.
"""
import json
import time
import urllib.request

import numpy as np
import pytest

from repro.control.telemetry import SloTelemetry, TieredTelemetry
from repro.obs.sketch import REL_ERR_BOUND, WindowedSketch
from repro.obs.spans import SpanRecord, SpanRecorder, collect, note

SLO = 0.3
WINDOW = 20.0


def _feed(rng, engines, n=3000, jitter=0.5):
    """Randomized trace with OUT-OF-ORDER timestamps (within-window
    jitter): every engine sees the identical event stream."""
    t = 0.0
    last = 0.0
    for _ in range(n):
        t += float(rng.exponential(0.01))
        tt = t + float(rng.uniform(-jitter, 0.0))   # late arrivals
        tt = max(tt, last - jitter)
        lat = float(rng.lognormal(-2.0, 0.7))
        kind = rng.uniform()
        for eng in engines:
            eng.record_arrival(tt)
            if kind < 0.85:
                eng.record_served(lat, tt)
            elif kind < 0.95:
                eng.record_shed(tt)
            else:
                eng.record_failure(tt)
        last = max(last, tt)
    return t


def _pair(clock):
    sk = SloTelemetry(SLO, WINDOW, clock=clock, exact=False)
    ex = SloTelemetry(SLO, WINDOW, clock=clock, exact=True)
    return sk, ex


# ------------------------------------------------- sketch equivalence
def test_sketch_counts_and_violation_rate_exact():
    """Counts and violation rate are EXACT (not approximate): the
    sketch's counters are plain sums, only quantiles are coarsened."""
    t = 0.0
    sk, ex = _pair(lambda: t)
    rng = np.random.default_rng(0)
    t = _feed(rng, (sk, ex))
    s, e = sk.snapshot(), ex.snapshot()
    assert s.n_arrivals == e.n_arrivals > 0
    assert s.n_served == e.n_served > 0
    assert s.n_shed == e.n_shed > 0
    assert s.n_failed == e.n_failed > 0
    assert s.violation_rate == pytest.approx(e.violation_rate, abs=1e-12)
    assert s.arrival_rate == pytest.approx(e.arrival_rate, rel=1e-9)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sketch_quantiles_within_histogram_bound(seed):
    t = 0.0
    sk, ex = _pair(lambda: t)
    rng = np.random.default_rng(seed)
    t = _feed(rng, (sk, ex))
    s, e = sk.snapshot(), ex.snapshot()
    assert s.p50 == pytest.approx(e.p50, rel=REL_ERR_BOUND)
    assert s.p99 == pytest.approx(e.p99, rel=REL_ERR_BOUND)


def test_sketch_tq_bound_within_one_bucket():
    """|sketch T_q - exact T_q| <= one sub-window bucket width, both
    directions (the sketch's mean-grouped trace can under- or
    over-state a burst by at most its within-bucket spread)."""
    t = 0.0
    sk, ex = _pair(lambda: t)
    rng = np.random.default_rng(3)
    t = _feed(rng, (sk, ex), n=2000)
    bw = sk.window / sk.n_buckets
    for mu in (50.0, 100.0, 200.0, 500.0):
        d = sk.queueing_bound(mu, 0.01) - ex.queueing_bound(mu, 0.01)
        assert abs(d) <= bw + 1e-9, (mu, d, bw)


def test_sketch_since_cut_matches_exact_within_one_bucket():
    """snapshot(since=...) on the sketch cuts on bucket boundaries:
    counts differ from the exact cut by at most the events of ONE
    bucket."""
    t = 0.0
    sk, ex = _pair(lambda: t)
    rng = np.random.default_rng(4)
    t = _feed(rng, (sk, ex), n=2000, jitter=0.0)
    since = t - 5.0
    s = sk.snapshot(since=since)
    e = ex.snapshot(since=since)
    bw = sk.window / sk.n_buckets
    # events in one bucket ~ n / (span/bw); be generous: 3 buckets
    slack = 3 * max(1, int(e.n_arrivals * bw / 5.0))
    assert abs(s.n_arrivals - e.n_arrivals) <= slack
    assert s.violation_rate == pytest.approx(e.violation_rate, abs=0.05)


def test_sketch_merge_equals_flat_feed():
    """merge(tier slices) == one flat-fed sketch: same counters, same
    histogram — the fleet view is a real reduction, not an estimate."""
    t = 0.0
    clock = lambda: t
    parts = [SloTelemetry(SLO, WINDOW, clock=clock) for _ in range(3)]
    flat = SloTelemetry(SLO, WINDOW, clock=clock)
    rng = np.random.default_rng(5)
    for _ in range(2000):
        t += float(rng.exponential(0.01))
        lat = float(rng.lognormal(-2.0, 0.7))
        p = parts[int(rng.integers(3))]
        for eng in (p, flat):
            eng.record_arrival(t)
            eng.record_served(lat, t)
    merged = SloTelemetry.merge(parts)
    m, f = merged.snapshot(), flat.snapshot()
    assert m.n_arrivals == f.n_arrivals
    assert m.n_served == f.n_served
    assert m.p50 == pytest.approx(f.p50, rel=1e-9)
    assert m.p99 == pytest.approx(f.p99, rel=1e-9)
    np.testing.assert_allclose(merged.latency_histogram(),
                               flat.latency_histogram())


def test_merge_rejects_mismatched_config():
    a = SloTelemetry(SLO, WINDOW)
    b = SloTelemetry(SLO, WINDOW * 2)
    with pytest.raises(ValueError):
        SloTelemetry.merge([a, b])
    with pytest.raises(ValueError):
        SloTelemetry.merge([a, SloTelemetry(SLO, WINDOW, exact=True)])


def test_tiered_fleet_is_derived_merge():
    t = 0.0
    tel = TieredTelemetry(lambda p: "crit" if p % 2 else "stable",
                          ("stable", "crit"), slo_seconds=SLO,
                          window_seconds=WINDOW, clock=lambda: t)
    rng = np.random.default_rng(6)
    for _ in range(500):
        t += float(rng.exponential(0.02))
        p = int(rng.integers(8))
        tel.record_arrival(t, patient=p)
        tel.record_served(float(rng.lognormal(-2.0, 0.5)), t, patient=p)
    fleet = tel.snapshot()
    by_tier = [tel.tier_snapshot(x) for x in ("stable", "crit")]
    assert fleet.n_arrivals == sum(s.n_arrivals for s in by_tier) == 500
    assert fleet.n_served == sum(s.n_served for s in by_tier)


# --------------------------------------------------------- O(1) memory
def test_sketch_memory_constant_over_100x_window():
    """A trace >= 100x the window leaves the sketch's arrays at their
    construction shape — O(1) in trace length, O(n_buckets) in space —
    while the exact oracle's logs would hold the full window."""
    sk = WindowedSketch(window_seconds=10.0, n_buckets=64)
    shape0 = (sk.counts.shape, sk.hist.shape)
    nbytes0 = sk.counts.nbytes + sk.hist.nbytes
    rng = np.random.default_rng(7)
    t = 0.0
    from repro.obs.sketch import ARRIVALS, SERVED
    for _ in range(20000):                       # ~200x the window
        t += float(rng.exponential(0.05))
        sk.add(ARRIVALS, t)
        sk.add(SERVED, t, latency=float(rng.lognormal(-2.0, 0.5)))
    assert (sk.counts.shape, sk.hist.shape) == shape0
    assert sk.counts.nbytes + sk.hist.nbytes == nbytes0
    # and it still answers: only ~window/mean_gap events remain live
    tot = sk.totals(t)
    assert 0 < tot[0] <= 10.0 / 0.05 * 1.5


def test_telemetry_sketch_mode_has_no_event_logs():
    tel = SloTelemetry(SLO, WINDOW, exact=False)
    with pytest.raises(AttributeError):
        tel._arrivals                      # oracle-only introspection
    assert SloTelemetry(SLO, WINDOW, exact=True)._arrivals is not None


# ------------------------------------------- exact engine (since cuts)
def test_exact_engine_since_cut_is_bisect_correct():
    """The head-offset/bisect since-cut must agree with brute-force
    filtering for arbitrary since positions."""
    t = 0.0
    tel = SloTelemetry(SLO, 1000.0, clock=lambda: t, exact=True)
    rng = np.random.default_rng(8)
    ts = np.sort(rng.uniform(0, 100, 500))
    for x in ts:
        t = float(x)
        tel.record_arrival(t)
        tel.record_served(0.1, t)
    for since in (-1.0, 0.0, 17.3, 50.0, 99.9, 200.0):
        snap = tel.snapshot(since=since)
        want = int(np.sum(ts > since))
        assert snap.n_arrivals == want, since
        assert snap.n_served == want, since


# ------------------------------------------------------------- spans
def test_note_outside_collect_is_noop():
    note("marshal", 1.0)                           # must not raise
    with collect() as acc:
        note("marshal", 0.25)
        note("marshal", 0.25)
        note("gather", 0.1)
    assert acc == {"marshal": 0.5, "gather": 0.1}


def test_collect_reentrancy_folds_into_outer():
    with collect() as outer:
        with collect() as inner:
            note("dispatch", 0.2)
        assert inner is outer
    assert outer == {"dispatch": 0.2}


def _span(status="ok", t0=0.0):
    return SpanRecord(patient=1, tier=None, status=status,
                      t_submit=t0, t_dequeue=t0 + 0.1,
                      t_flush=t0 + 0.15, t_retire=t0 + 0.55, batch_n=4,
                      marshal_s=0.05, dispatch_s=0.25, gather_s=0.08)


def test_span_record_telescopes():
    s = _span()
    assert s.queue_s == pytest.approx(0.1)
    assert s.coalesce_s == pytest.approx(0.05)
    assert s.service_s == pytest.approx(0.4)
    assert s.e2e_s == pytest.approx(0.55)
    # service stages are a subset of service_s
    assert s.marshal_s + s.dispatch_s + s.gather_s <= s.service_s + 1e-9


def test_recorder_attribution_and_coverage():
    rec = SpanRecorder(keep=16)
    for i in range(40):                     # > keep: ring must bound
        rec.record(_span(t0=float(i)))
    assert rec.n_spans == 40
    assert len(rec.spans()) == 16
    att = rec.attribution()
    assert att["n_spans"] == 40
    assert att["by_status"] == {"ok": 40}
    # every stage measured -> coverage explains e2e fully here
    measured = sum(att["stage_seconds"].values())
    assert att["coverage"] == pytest.approx(measured / att["e2e_seconds"])
    assert 0.0 < att["coverage"] <= 1.0 + 1e-9
    assert rec.e2e_quantile(50) == pytest.approx(0.55, rel=REL_ERR_BOUND)


def test_server_emits_spans_with_failure_statuses():
    """End-to-end through a real EnsembleServer: ok spans from normal
    queries, a 'failed' span for a NaN score, and a 'watchdog' span for
    a stalled co-batch."""
    from repro.serving.server import EnsembleServer

    rec = SpanRecorder()
    stall = {"on": False}

    def handler(batch):
        with collect():
            note("marshal", 0.001)
        if stall["on"]:
            time.sleep(1.0)                      # > deadline
        return [float("nan") if w.get("poison") else 1.0
                for w in batch]

    srv = EnsembleServer(batch_handler=handler, n_workers=1,
                         max_batch=4, max_wait_ms=1.0,
                         deadline_seconds=0.2, watchdog_interval=0.02,
                         tracer=rec).start()
    for p in range(4):
        srv.submit(p, {})
    srv.submit(99, {"poison": True})
    srv.drain(timeout=10.0)
    stall["on"] = True
    srv.submit(7, {})
    deadline = time.monotonic() + 5.0
    while "watchdog" not in rec.n_by_status \
            and time.monotonic() < deadline:
        time.sleep(0.02)
    stall["on"] = False
    srv.stop()
    statuses = rec.attribution()["by_status"]
    assert statuses.get("ok", 0) >= 4
    assert statuses.get("failed", 0) >= 1
    assert statuses.get("watchdog", 0) >= 1


# ------------------------------------------------------------- export
def _traced_server():
    from repro.obs.export import MetricsExporter
    from repro.serving.server import EnsembleServer

    tel = SloTelemetry(1.0, 10.0)
    rec = SpanRecorder()
    srv = EnsembleServer(batch_handler=lambda b: [1.0] * len(b),
                         n_workers=1, telemetry=tel, tracer=rec).start()
    for p in range(6):
        srv.submit(p, {})
    srv.drain(timeout=10.0)
    srv.stop()
    return MetricsExporter(server=srv, telemetry=tel, tracer=rec), rec


def test_prometheus_render_format():
    exporter, _ = _traced_server()
    text = exporter.render()
    lines = text.splitlines()
    assert any(l.startswith("# TYPE holmes_served_total counter")
               for l in lines)
    assert any(l.startswith("holmes_served_total 6") for l in lines)
    assert any(l.startswith("holmes_window_p99{tier=\"fleet\"}")
               for l in lines)
    assert any("holmes_latency_seconds_bucket{le=" in l for l in lines)
    assert any(l.startswith("holmes_span_stage_seconds_total"
                            "{stage=\"queue\"}") for l in lines)
    # exposition discipline: every non-comment line is "name value"
    for l in lines:
        if not l or l.startswith("#"):
            continue
        name, _, val = l.rpartition(" ")
        assert name and (val == "NaN" or float(val) == float(val))


def test_metrics_http_endpoint_scrapes():
    from repro.obs.export import start_metrics_server
    exporter, _ = _traced_server()
    httpd = start_metrics_server(exporter, port=0)
    try:
        base = f"http://127.0.0.1:{httpd.server_port}"
        with urllib.request.urlopen(base + "/metrics", timeout=10) as r:
            assert r.status == 200
            body = r.read().decode()
        assert "holmes_served_total 6" in body
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(base + "/nope", timeout=10)
    finally:
        httpd.shutdown()


def test_jsonl_span_export_round_trips(tmp_path):
    from repro.obs.export import write_spans_jsonl
    _, rec = _traced_server()
    path = tmp_path / "spans.jsonl"
    n = write_spans_jsonl(rec, str(path))
    assert n == 6
    rows = [json.loads(l) for l in path.read_text().splitlines()]
    assert len(rows) == 6
    for row in rows:
        assert row["status"] == "ok"
        assert row["e2e_s"] >= row["queue"] >= 0.0


# ---------------------------------------- controller decisions parity
@pytest.mark.slow
def test_controller_decisions_identical_under_sketch():
    """The acceptance criterion end-to-end: seeded DES runs driven by
    the sketch take the SAME action log as under the exact oracle."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.adaptive_bench import (run_adaptive_sim,
                                           synthetic_testbed)
    zoo, costs, f_a = synthetic_testbed(seed=0)
    sched = [(3, 16), (4, 48), (3, 16)]
    runs = [run_adaptive_sim(zoo, costs, f_a, 1.0, sched, adaptive=True,
                             seed=0, telemetry_exact=exact)
            for exact in (False, True)]
    assert runs[0]["actions"] == runs[1]["actions"]
    assert runs[0]["actions"], "run took no actions — nothing compared"
