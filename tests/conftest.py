"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see the single real CPU device; only launch/dryrun.py forces 512
placeholder devices (and runs in its own process).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def icu_data():
    from repro.training.data import make_icu_dataset, split_by_patient
    data = make_icu_dataset(n_patients=12, clips_per_patient=8, seed=0,
                            seconds=3)
    return split_by_patient(data, holdout=4)
