"""Shared fixtures.  NOTE: no XLA_FLAGS by default — smoke tests and
benches must see the single real CPU device; only launch/dryrun.py
forces 512 placeholder devices (and runs in its own process).

The EXCEPTION is the multi-device lane: setting ``REPRO_MULTI_DEVICE=1``
(or exporting ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
directly, as the CI lane does) forces 8 host devices BEFORE jax
initialises, so the ``multi_device``-marked placement tests run
in-process.  In the default single-device lane those tests skip and
``test_placement_serving.py``'s subprocess wrapper re-runs them in a
child with the flag set.

Heavy integration tests carry ``@pytest.mark.slow`` (registered below) so
``pytest -m "not slow"`` gives a fast signal; the shared zoo fixtures are
session-scoped so the default run builds/trains each zoo exactly once.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# env-guarded multi-device lane: must happen before anything imports jax
if os.environ.get("REPRO_MULTI_DEVICE"):
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: heavy integration test (deselect with -m 'not slow')")
    config.addinivalue_line(
        "markers",
        "multi_device: needs >= 8 forced host devices (XLA_FLAGS / "
        "REPRO_MULTI_DEVICE lane, or the subprocess wrapper in "
        "test_placement_serving.py)")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def icu_data():
    from repro.training.data import make_icu_dataset, split_by_patient
    data = make_icu_dataset(n_patients=12, clips_per_patient=8, seed=0,
                            seconds=3)
    return split_by_patient(data, holdout=4)


@pytest.fixture(scope="session")
def small_zoo():
    """Trained reduced zoo + extras (cached on disk by zoo_setup);
    shared session-wide by integration/serving tests."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.zoo_setup import build_zoo
    return build_zoo(n_patients=12, clips=6, steps=60, seconds=3,
                     verbose=False)


@pytest.fixture(scope="session")
def zoo_members():
    """Randomly-initialised reduced-zoo members (short clips) — the
    shared substrate for fused-serving/equivalence tests, where member
    WEIGHTS don't matter but shapes and bucketing do."""
    import jax
    from repro.configs.ecg_zoo import zoo_specs
    from repro.models.ecg_resnext import init_ecg
    from repro.serving.pipeline import ZooMember
    specs = zoo_specs(reduced=True, input_len=250)
    return [ZooMember(s, init_ecg(jax.random.PRNGKey(i), s))
            for i, s in enumerate(specs)]
