"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see the single real CPU device; only launch/dryrun.py forces 512
placeholder devices (and runs in its own process).

Heavy integration tests carry ``@pytest.mark.slow`` (registered below) so
``pytest -m "not slow"`` gives a fast signal; the shared zoo fixtures are
session-scoped so the default run builds/trains each zoo exactly once.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: heavy integration test (deselect with -m 'not slow')")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def icu_data():
    from repro.training.data import make_icu_dataset, split_by_patient
    data = make_icu_dataset(n_patients=12, clips_per_patient=8, seed=0,
                            seconds=3)
    return split_by_patient(data, holdout=4)


@pytest.fixture(scope="session")
def small_zoo():
    """Trained reduced zoo + extras (cached on disk by zoo_setup);
    shared session-wide by integration/serving tests."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.zoo_setup import build_zoo
    return build_zoo(n_patients=12, clips=6, steps=60, seconds=3,
                     verbose=False)


@pytest.fixture(scope="session")
def zoo_members():
    """Randomly-initialised reduced-zoo members (short clips) — the
    shared substrate for fused-serving/equivalence tests, where member
    WEIGHTS don't matter but shapes and bucketing do."""
    import jax
    from repro.configs.ecg_zoo import zoo_specs
    from repro.models.ecg_resnext import init_ecg
    from repro.serving.pipeline import ZooMember
    specs = zoo_specs(reduced=True, input_len=250)
    return [ZooMember(s, init_ecg(jax.random.PRNGKey(i), s))
            for i, s in enumerate(specs)]
