"""Chaos-hardening invariants: the deterministic fault plane, the
bounded priority queue, the watchdog, and device-loss failover.

Units here run against toy handlers (no zoo training) so the fault
semantics — exactly-once retirement, conservation under eviction,
heartbeat vs. silent stall, minimal-move failover plans — are checked
fast and deterministically; the end-to-end soak (real zoo, real
ingest, bitwise oracle) lives in ``benchmarks/chaos_bench.py`` and its
smoke test below.
"""
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

import jax

from repro.control.faults import (DeviceLostError, FaultEvent, FaultPlane)
from repro.serving.queues import ShedQueue
from repro.serving.server import EnsembleServer

N_FORCED = 8
IN_LANE = jax.device_count() >= N_FORCED

needs_devices = pytest.mark.skipif(
    not IN_LANE,
    reason=f"needs {N_FORCED} forced host devices (CI lane or the "
           f"subprocess wrapper below)")
multi_device = pytest.mark.multi_device


# ---------------------------------------------------------- FaultPlane
class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_fault_event_rejects_unknown_kind():
    with pytest.raises(ValueError):
        FaultEvent(0.0, "meteor_strike")


def test_fault_plane_fires_in_schedule_order():
    clk = FakeClock()
    plane = FaultPlane([FaultEvent(2.0, "worker_stall", duration=0.3),
                        FaultEvent(1.0, "backpressure", duration=0.5)],
                       clock=clk)
    plane.arm(devices=[object()])
    assert not plane.done()
    assert plane.stall_pending() == 0.0       # nothing due yet
    clk.t = 1.1
    assert plane.backpressure_active()
    assert plane.stall_pending() == 0.0
    clk.t = 1.7
    assert not plane.backpressure_active()    # episode over
    clk.t = 2.1
    assert plane.stall_pending() == 0.3
    assert plane.stall_pending() == 0.0       # token consumed exactly once
    assert plane.done()
    assert [ev.kind for _, ev in plane.fired] == ["backpressure",
                                                  "worker_stall"]


def test_fault_plane_guard_raises_for_lost_device_only():
    clk = FakeClock()
    d0, d1 = object(), object()
    plane = FaultPlane([FaultEvent(1.0, "device_loss", target=1)],
                       clock=clk)
    plane.arm(devices=[d0, d1])
    plane.guard(d0)
    plane.guard(d1)                           # not lost yet
    clk.t = 1.0
    plane.guard(d0)                           # survivor stays fine
    with pytest.raises(DeviceLostError) as ei:
        plane.guard(d1)
    assert ei.value.index == 1
    assert ei.value.device is d1


def test_fault_plane_transient_loss_expires():
    clk = FakeClock()
    d0 = object()
    plane = FaultPlane(
        [FaultEvent(1.0, "device_loss", target=0, duration=0.5)],
        clock=clk)
    plane.arm(devices=[d0])
    clk.t = 1.2
    with pytest.raises(DeviceLostError):
        plane.guard(d0)                       # None also targets idx 0
    clk.t = 1.6
    plane.guard(d0)                           # device "rebooted"
    assert [r["kind"] for r in plane.recoveries] == ["device_restored"]


def test_protect_transient_loss_serves_late_and_heartbeats():
    clk = FakeClock()
    d0 = object()
    plane = FaultPlane(
        [FaultEvent(0.0, "device_loss", target=0, duration=0.2)],
        clock=clk)
    plane.arm(devices=[d0])
    calls = {"n": 0}
    beats = []

    def score(windows):
        calls["n"] += 1
        clk.t += 0.06                   # wall time passes per attempt
        plane.guard(d0)
        return [1.0] * len(windows)

    guarded = plane.protect(score, heartbeat=lambda: beats.append(1)
                            or True, retry_sleep=0.0)
    assert guarded([{}, {}]) == [1.0, 1.0]
    assert calls["n"] > 1               # really retried through the loss
    assert beats                        # and heart-beat while waiting


def test_protect_gives_up_after_budget():
    clk = FakeClock()
    d0 = object()
    plane = FaultPlane(
        [FaultEvent(0.0, "device_loss", target=0, duration=0.0)],
        clock=clk)
    plane.arm(devices=[d0])             # permanent, no swapper: hopeless

    calls = {"n": 0}

    def score(windows):
        calls["n"] += 1
        clk.t += 0.02                   # injected time passes per try
        plane.guard(d0)
        return [1.0]

    guarded = plane.protect(score, retry_budget_s=0.05, retry_sleep=0.0)
    with pytest.raises(DeviceLostError):
        guarded([{}])
    assert 2 <= calls["n"] <= 10        # retried, then gave up on the
    #                                     INJECTED clock's budget


def test_protect_abandoned_cobatch_stops_retrying():
    """heartbeat() returning False (watchdog gave up) must end the
    retry loop immediately — the scores would be discarded anyway."""
    clk = FakeClock()
    d0 = object()
    plane = FaultPlane(
        [FaultEvent(0.0, "device_loss", target=0, duration=1.0)],
        clock=clk)
    plane.arm(devices=[d0])

    def score(windows):
        plane.guard(d0)
        return [1.0]

    guarded = plane.protect(score, heartbeat=lambda: False,
                            retry_budget_s=30.0, retry_sleep=0.0)
    t0 = time.monotonic()
    with pytest.raises(DeviceLostError):
        guarded([{}])
    assert time.monotonic() - t0 < 5.0


# ----------------------------------------------------------- ShedQueue
def test_shed_queue_bounds_unfinished_not_just_queued():
    import queue as _queue
    q = ShedQueue(maxsize=2)
    q.put_nowait("a")
    q.put_nowait("b")
    with pytest.raises(_queue.Full):
        q.put_nowait("c")
    q.get(timeout=0.1)                  # popped but NOT task_done yet:
    with pytest.raises(_queue.Full):    # in-flight still holds the slot
        q.put_nowait("c")
    q.task_done()
    q.put_nowait("c")                   # slot released


def test_shed_queue_eviction_priority_and_order():
    q = ShedQueue(maxsize=3)
    q.put_nowait("s1", priority=0.0, tag="stable")
    q.put_nowait("c1", priority=2.0, tag="critical")
    q.put_nowait("s2", priority=0.0, tag="stable")
    # full; a critical newcomer evicts the OLDEST strictly-lower item
    ok, victim = q.put_evicting("c2", priority=2.0, tag="critical")
    assert ok and victim == ("s1", "stable")
    assert q.qsize() == 3
    ok, victim = q.put_evicting("c3", priority=2.0, tag="critical")
    assert ok and victim == ("s2", "stable")       # next-oldest stable
    # all-critical queue: equal priority is NOT strictly lower — no
    # victim, newcomer not admitted
    ok, victim = q.put_evicting("c4", priority=2.0, tag="critical")
    assert not ok and victim is None
    assert [q.get(timeout=0.1) for _ in range(3)] == ["c1", "c2", "c3"]


def test_shed_queue_eviction_conserves_unfinished():
    q = ShedQueue(maxsize=2)
    q.put_nowait("s1", priority=0.0)
    q.put_nowait("s2", priority=0.0)
    ok, victim = q.put_evicting("c1", priority=1.0)
    assert ok and victim is not None
    # the victim's slot transferred to the newcomer: still 2 unfinished
    assert q.unfinished_tasks == 2
    q.get(timeout=0.1), q.get(timeout=0.1)
    q.task_done(), q.task_done()
    assert q.unfinished_tasks == 0
    with pytest.raises(ValueError):
        q.task_done()                   # underflow must be loud


# ---------------------------------------------- watchdog + NaN-isolation
def test_watchdog_fails_stalled_cobatch_and_respawns():
    """A silently hung handler: the watchdog NaN-fails the in-flight
    co-batch within the deadline, respawns the worker, and later
    queries are served by the replacement — with exactly-once
    retirement (conservation) throughout."""
    stall_once = threading.Event()

    def batch_handler(windows):
        if not stall_once.is_set():
            stall_once.set()
            time.sleep(1.2)             # silent: no heartbeat
        return [1.0] * len(windows)

    srv = EnsembleServer(batch_handler=batch_handler, n_workers=1,
                         max_batch=2, max_wait_ms=1.0,
                         deadline_seconds=0.15,
                         watchdog_interval=0.01).start()
    srv.submit(0, {})
    time.sleep(0.5)                     # watchdog fires mid-stall
    for i in range(1, 5):
        srv.submit(i, {})
    stats = srv.stop()
    assert stats.served == 5
    assert stats.stalls >= 1
    assert stats.failed >= 1
    scores = {p: s for p, s, *_ in srv.results()}
    assert np.isnan(scores[0])          # the stalled co-batch: NaN
    assert all(scores[i] == 1.0 for i in range(1, 5))
    assert not srv.leaked               # replacement + stalled worker


def test_watchdog_survives_thread_ident_reuse(monkeypatch):
    """Regression: watchdog bookkeeping used to be keyed by
    ``threading.get_ident()``.  The OS reuses idents once a thread
    exits, so a replacement worker could inherit its stalled
    predecessor's ``_abandoned`` entry and silently DISCARD a healthy
    co-batch — the query never retired and conservation broke.  Force
    the worst case (every thread reports the SAME ident) and run a
    stall-then-healthy sequence: the healthy query must still retire
    with its real score."""
    import repro.serving.server as server_mod

    class _SameIdent:
        """``threading`` facade whose get_ident collides for everyone
        (deterministic stand-in for OS-level ident reuse)."""

        def __getattr__(self, name):
            if name == "get_ident":
                return lambda: 0xDEAD
            return getattr(threading, name)

    monkeypatch.setattr(server_mod, "threading", _SameIdent())
    stalled = threading.Event()
    release = threading.Event()

    def batch_handler(windows):
        if not stalled.is_set():
            stalled.set()
            release.wait(5.0)           # silent stall: no heartbeat
        return [1.0] * len(windows)

    srv = EnsembleServer(batch_handler=batch_handler, n_workers=1,
                         max_batch=1, max_wait_ms=0.5,
                         deadline_seconds=0.1,
                         watchdog_interval=0.01).start()
    srv.submit(0, {})                   # stalls worker 1
    deadline = time.monotonic() + 2.0
    while srv.stats.stalls < 1 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert srv.stats.stalls == 1        # watchdog fired, worker 2 up
    srv.submit(1, {})                   # healthy query on the new worker
    while srv.stats.served < 2 and time.monotonic() < deadline:
        time.sleep(0.01)
    # pre-fix: worker 2 shares worker 1's ident, finds itself in
    # _abandoned, discards the healthy scores and exits — served stays 1
    assert srv.stats.served == 2
    release.set()
    stats = srv.stop()
    assert stats.served == 2 and stats.failed == 1
    scores = {p: s for p, s, *_ in srv.results()}
    assert np.isnan(scores[0]) and scores[1] == 1.0
    assert not srv.leaked


def test_heartbeat_keeps_slow_recovery_alive():
    """A handler WAITING (and heart-beating) past the deadline is not a
    stall: the co-batch must be served late and REAL, the watchdog must
    not fire."""
    def batch_handler(windows):
        t_end = time.monotonic() + 0.5  # 'recovery' far past deadline
        while time.monotonic() < t_end:
            assert srv.heartbeat()
            time.sleep(0.02)
        return [1.0] * len(windows)

    srv = EnsembleServer(batch_handler=batch_handler, n_workers=1,
                         max_batch=2, max_wait_ms=1.0,
                         deadline_seconds=0.15,
                         watchdog_interval=0.01).start()
    srv.submit(0, {})
    stats = srv.stop()
    assert stats.served == 1
    assert stats.stalls == 0
    assert stats.failed == 0
    (_, score, *_), = srv.results()
    assert score == 1.0


def test_heartbeat_reports_abandonment():
    """If the handler only starts heart-beating AFTER the watchdog gave
    up, heartbeat() returns False — the late scores are discarded and
    the query has already been NaN-retired exactly once."""
    seen = []
    release = threading.Event()

    def batch_handler(windows):
        release.wait(timeout=5.0)       # silent past the deadline
        seen.append(srv.heartbeat())
        return [1.0] * len(windows)

    srv = EnsembleServer(batch_handler=batch_handler, n_workers=1,
                         max_batch=2, max_wait_ms=1.0,
                         deadline_seconds=0.1,
                         watchdog_interval=0.01).start()
    srv.submit(0, {})
    time.sleep(0.4)                     # watchdog abandons the co-batch
    release.set()
    stats = srv.stop()
    assert seen == [False]
    assert stats.served == 1 and stats.failed == 1
    (_, score, *_), = srv.results()
    assert np.isnan(score)


# ------------------------------------- stale/fresh co-batch isolation
def test_safe_batch_mixed_stale_fresh_cobatch(zoo_members):
    """Satellite: a STALE DeviceWindowRef co-batched with fresh ones —
    the flush raises on the stale ref, the NaN-retry isolates it, and
    every fresh co-batched query still scores bitwise-identically to
    the same window scored without the fault (the retry path scores
    survivors singly, so the oracle is the single-query flush)."""
    from repro.configs.ecg_zoo import ECG_LEADS
    from repro.serving.aggregator import DeviceIngest, ModalitySpec
    from repro.serving.pipeline import EnsembleService

    members = zoo_members[:3]
    L = members[0].spec.input_len
    svc = EnsembleService(members)
    di = DeviceIngest([ModalitySpec("ecg", float(L), ECG_LEADS)],
                      n_patients=3, window_seconds=1.0,
                      capacity_windows=2.0)
    rng = np.random.default_rng(0)
    refs, wins = [], []
    for p in range(3):
        sig = rng.standard_normal((ECG_LEADS, L)).astype(np.float32)
        di.ingest(float(p), p, "ecg", sig)
        refs.append(di.close_window(p, float(p) + 1.0))
        wins.append(sig)
    want = {p: svc.predict_batch([{"ecg": wins[p]}])[0] for p in (0, 2)}

    # age OUT patient 1's ref: stream enough fresh samples that the
    # ring guard must refuse the overwritten window
    cap = di.states["ecg"].buf.shape[-1]
    for _ in range(int(np.ceil(cap / L)) + 1):
        di.ingest(99.0, 1, "ecg",
                  rng.standard_normal((ECG_LEADS, L)).astype(np.float32))

    srv = EnsembleServer(batch_handler=svc.predict_batch, n_workers=1,
                         max_batch=4, max_wait_ms=50.0).start()
    for p, r in enumerate(refs):
        srv.submit(p, r)
    stats = srv.stop()
    assert stats.served == 3 and stats.failed == 1
    scores = {p: s for p, s, *_ in srv.results()}
    assert np.isnan(scores[1])          # stale: refused, never mis-scored
    assert scores[0] == want[0] and scores[2] == want[2]   # bitwise


# -------------------------------------------- priority backpressure
def test_priority_backpressure_critical_never_rejected():
    """Overrun a bounded server with stable-tier floods: sheds are
    stable-only, every critical admission succeeds (by eviction when
    full), and the rejection ledger conserves every submission."""
    release = threading.Event()

    def batch_handler(windows):
        release.wait(timeout=10.0)      # hold workers: queue must fill
        return [1.0] * len(windows)

    # few criticals relative to the queue bound: priority admission
    # must cover them all by evicting queued stables
    tier_of = lambda p: "critical" if p % 10 == 0 else "stable"
    srv = EnsembleServer(batch_handler=batch_handler, n_workers=1,
                         max_batch=2, max_wait_ms=1.0, max_queue=8,
                         tier_of=tier_of,
                         tier_priority={"critical": 2,
                                        "stable": 0}).start()
    critical_admitted = 0
    submitted = 0
    for p in list(range(30)):
        ok = srv.submit(p, {"p": p})
        submitted += 1
        if ok and tier_of(p) == "critical":
            critical_admitted += 1
    release.set()
    stats = srv.stop()
    assert stats.rejected.get("critical", 0) == 0
    assert critical_admitted == sum(1 for p in range(30)
                                    if tier_of(p) == "critical")
    assert stats.shed == stats.rejected.get("stable", 0) > 0
    # conservation across the whole ledger: every submit either served
    # or counted shed (an evicted victim is shed; its slot was reused)
    assert stats.served + stats.shed == submitted


# ------------------------------------------------- failover placement
def test_failover_placement_minimal_move():
    from repro.control.swap import HotSwapper
    from repro.serving.placement import Placement
    old = Placement(assignment=[[0, 1], [2], [3, 4]],
                    loads=[2.0, 5.0, 1.0])
    pl = HotSwapper._failover_placement(old, dead_slot=1)
    # survivors untouched, dead slot's members on the least-loaded
    assert pl.assignment == [[0, 1], [3, 4, 2]]
    assert pl.loads == [2.0, 6.0]
    assert pl.n_members == old.n_members
    # degenerate shapes fall back to full re-derivation
    assert HotSwapper._failover_placement(None, 0) is None
    assert HotSwapper._failover_placement(old, 7) is None
    assert HotSwapper._failover_placement(
        Placement(assignment=[[0]], loads=[1.0]), 0) is None


@multi_device
@needs_devices
def test_quarantine_failover_serves_bitwise(zoo_members):
    """Permanent device loss on the sharded lane: quarantine swaps the
    active selector onto the minimal-move survivor plan, the dead
    device leaves the pool, and post-failover scores stay bitwise equal
    to the unsharded reference."""
    from repro.control.swap import HotSwapper
    from repro.serving.pipeline import EnsembleService

    members = zoo_members
    sel = np.ones(len(members), np.int8)
    sw = HotSwapper(members, sel, n_devices=4,
                    warmup_batch_sizes=(1, 2))
    L = members[0].spec.input_len
    rng = np.random.default_rng(0)
    batch = [{"ecg": rng.standard_normal((3, L)).astype(np.float32)}
             for _ in range(2)]
    want = EnsembleService.for_selector(members, sel).predict_batch(batch)
    assert sw.facade.predict_batch(batch) == want

    dead = jax.devices()[1]
    old_gen = sw._devices_gen
    assert sw.quarantine_device(dead) is True
    assert dead not in (sw.devices or [])
    assert dead in sw.quarantined
    assert sw._devices_gen == old_gen + 1
    assert sw.facade.predict_batch(batch) == want      # bitwise across
    # second loss of the same device is a no-op refusal
    assert sw.quarantine_device(dead) is False


def test_quarantine_refuses_unsharded():
    from repro.control.swap import HotSwapper

    # unsharded swapper: nothing to fail over to
    sw = HotSwapper.__new__(HotSwapper)
    sw.placement_fn = None
    sw.n_devices = 1
    assert sw.quarantine_device(object()) is False


@pytest.mark.skipif(IN_LANE, reason="already in the multi-device lane")
def test_multi_device_chaos_subprocess():
    """Default lane: re-run this module's ``multi_device`` selection in
    a child with 8 forced host devices (mirrors the placement suite's
    wrapper) so quarantine failover is covered on every tier-1 run."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count"
                        f"={N_FORCED}")
    env.pop("PYTEST_CURRENT_TEST", None)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         os.path.abspath(__file__), "-m", "multi_device"],
        cwd=repo, env=env, capture_output=True, text=True, timeout=900)
    tail = (r.stdout or "") + (r.stderr or "")
    assert r.returncode == 0, tail[-4000:]
    assert " passed" in r.stdout, tail[-2000:]
    assert " skipped" not in r.stdout, tail[-2000:]


# ------------------------------------------------------ soak smoke
@pytest.mark.slow
def test_chaos_soak_single_device_smoke():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.chaos_bench import check_chaos_schema, run_chaos
    out = run_chaos(n_patients=4, windows_per_patient=6, n_devices=1,
                    seed=0, verbose=False)
    check_chaos_schema(out)
