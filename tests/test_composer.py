"""Ensemble composer (Algorithm 1/2) unit + property tests."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_shim import given, settings, st

from repro.core.baselines import (accuracy_first, latency_first, npo,
                                  random_baseline)
from repro.core.bagging import bagging_predict, roc_auc
from repro.core.composer import ComposerParams, compose
from repro.core.genetic import explore, mutation, recombination
from repro.core.objective import (AccuracyConstrainedObjective,
                                  LatencyConstrainedObjective, hard_delta,
                                  soft_delta)


def make_testbed(n=16, n_val=300, seed=0):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 2, n_val)
    quality = rng.uniform(0.3, 2.0, n)
    scores = np.stack([
        1 / (1 + np.exp(-(q * (2 * y - 1) + rng.normal(0, 2.0, n_val))))
        for q in quality])
    lat = rng.uniform(0.02, 0.12, n)

    def f_a(b):
        return roc_auc(y, bagging_predict(scores, b))

    def f_l(b):
        b = np.asarray(b, bool)
        return float(lat[b].sum() * 0.7 + 0.01)
    return n, f_a, f_l, lat, scores, y


# ------------------------------------------------------------ genetic
@given(st.integers(1, 30), st.integers(1, 5), st.integers(0, 10 ** 6))
@settings(max_examples=40, deadline=None)
def test_mutation_manhattan_distance(n, S, seed):
    rng = np.random.default_rng(seed)
    b = rng.integers(0, 2, n).astype(np.int8)
    out = mutation(b, S, rng)
    d = int(np.abs(out - b).sum())
    assert d == min(S, n)
    assert set(np.unique(out)) <= {0, 1}


@given(st.integers(2, 30), st.integers(0, 10 ** 6))
@settings(max_examples=40, deadline=None)
def test_recombination_prefix_suffix(n, seed):
    rng = np.random.default_rng(seed)
    b1 = rng.integers(0, 2, n).astype(np.int8)
    b2 = rng.integers(0, 2, n).astype(np.int8)
    out = recombination(b1, b2, rng)
    # every position comes from one parent
    assert np.all((out == b1) | (out == b2))


@given(st.integers(4, 20), st.integers(1, 50), st.integers(0, 10 ** 5))
@settings(max_examples=30, deadline=None)
def test_explore_no_duplicates(n, m, seed):
    rng = np.random.default_rng(seed)
    B = rng.integers(0, 2, (5, n)).astype(np.int8)
    out = explore(B, m, 2, 0.8, 0.5, rng)
    seen = {row.tobytes() for row in B}
    for row in out:
        key = row.tobytes()
        assert key not in seen
        seen.add(key)


# ------------------------------------------------------------ objective
def test_hard_delta():
    assert hard_delta(-0.01) == -np.inf
    assert hard_delta(0.0) == 0.0
    obj = LatencyConstrainedObjective(0.2)
    assert obj(0.9, 0.25) == -np.inf
    assert obj(0.9, 0.15) == 0.9


def test_soft_delta_one_sided():
    d = soft_delta(2.0)
    assert d(0.5) == 0.0          # slack is not rewarded
    assert d(-0.1) == pytest.approx(-0.2)


def test_accuracy_constrained_dual():
    obj = AccuracyConstrainedObjective(0.9)
    assert obj(0.95, 0.3) == pytest.approx(-0.3)
    assert obj(0.85, 0.1) == -np.inf


# ------------------------------------------------------------ bagging
@given(st.integers(1, 8), st.integers(5, 40), st.integers(0, 10 ** 5))
@settings(max_examples=30, deadline=None)
def test_bagging_bounds(n_models, n_samples, seed):
    rng = np.random.default_rng(seed)
    scores = rng.uniform(0, 1, (n_models, n_samples))
    b = rng.integers(0, 2, n_models)
    out = bagging_predict(scores, b)
    assert out.shape == (n_samples,)
    assert np.all(out >= 0) and np.all(out <= 1)
    if b.sum() == 1:
        np.testing.assert_allclose(out, scores[b.astype(bool)][0])


def test_roc_auc_perfect_and_random():
    y = np.asarray([0, 0, 1, 1])
    assert roc_auc(y, np.asarray([0.1, 0.2, 0.8, 0.9])) == 1.0
    assert roc_auc(y, np.asarray([0.9, 0.8, 0.2, 0.1])) == 0.0
    assert roc_auc(y, np.asarray([0.5, 0.5, 0.5, 0.5])) == 0.5


# ------------------------------------------------------------ composer
def test_composer_respects_hard_constraint():
    n, f_a, f_l, lat, _, _ = make_testbed()
    res = compose(n, f_a, f_l, latency_budget=0.15,
                  params=ComposerParams(N=6, M=60, K=4, N0=8, seed=3))
    assert res.feasible
    assert res.latency <= 0.15 + 1e-9
    assert f_l(res.b_star) == pytest.approx(res.latency)


def test_composer_beats_or_matches_singles():
    n, f_a, f_l, lat, scores, y = make_testbed(seed=2)
    budget = 0.2
    res = compose(n, f_a, f_l, budget,
                  params=ComposerParams(N=10, M=100, K=6, seed=2))
    best_single = max(
        f_a(np.eye(n, dtype=np.int8)[i]) for i in range(n)
        if lat[i] * 0.7 + 0.01 <= budget)
    assert res.accuracy >= best_single - 1e-9


def test_composer_infeasible_budget():
    n, f_a, f_l, *_ = make_testbed()
    res = compose(n, f_a, f_l, latency_budget=1e-6,
                  params=ComposerParams(N=3, M=30, K=3, seed=0))
    assert not res.feasible


@pytest.mark.parametrize("seed", [0, 1])
def test_baselines_and_composer_ordering(seed):
    """The paper's qualitative claim: HOLMES >= NPO on the final
    feasible accuracy, with the same profiler budget."""
    n, f_a, f_l, lat, scores, y = make_testbed(n=18, seed=seed)
    budget = 0.18
    single_acc = np.array([f_a(np.eye(n, dtype=np.int8)[i])
                           for i in range(n)])
    rd = random_baseline(n, f_a, f_l, budget, seed=seed)
    af = accuracy_first(n, f_a, f_l, budget, single_acc)
    lf = latency_first(n, f_a, f_l, budget, lat)
    warm = [r.b_star for r in (rd, af, lf)]
    calls = 10 * 6 + 12
    nr = npo(n, f_a, f_l, budget, max_subset=max(1, int(lf.b_star.sum())),
             n_calls=calls, seed=seed, warm_start=warm)
    hb = compose(n, f_a, f_l, budget,
                 ComposerParams(N=10, K=6, N0=12, seed=seed),
                 warm_start=warm)
    for r in (rd, af, lf, nr, hb):
        if r.feasible:
            assert r.latency <= budget + 1e-9
    assert hb.accuracy >= nr.accuracy - 0.005


def test_surrogate_r2_improves():
    """Fig. 8: surrogate R2 on an independent UNexplored validation set
    (drawn from the same small-ensemble regime the search explores —
    random forests cannot extrapolate outside the visited size range)."""
    n, f_a, f_l, *_ = make_testbed(n=16, seed=4)
    rng = np.random.default_rng(0)
    held = []
    for _ in range(50):
        size = int(rng.integers(1, max(2, n // 2)))
        b = np.zeros(n, np.int8)
        b[rng.choice(n, size=size, replace=False)] = 1
        held.append(b)
    held = np.stack(held)
    ha = np.asarray([f_a(b) for b in held])
    hl = np.asarray([f_l(b) for b in held])
    res = compose(n, f_a, f_l, 0.2,
                  ComposerParams(N=12, M=80, K=8, seed=0),
                  heldout_B=held, heldout_acc=ha, heldout_lat=hl)
    r2_last = max(h["r2_lat"] for h in res.history[-3:])
    r2_acc_last = max(h["r2_acc"] for h in res.history[-3:])
    assert r2_last > 0.5                  # latency surrogate is good
    assert r2_acc_last > max(
        h["r2_acc"] for h in res.history[:1]) - 0.1
